//! The coordinator side of the elastic 2PC epoch protocol as a pure
//! state machine.
//!
//! [`CoordinatorSm`] owns every membership decision the fleet makes —
//! epoch formation, ack collection, the drain-or-discard ruling, grace
//! draining after churn, and fleet completion — but performs no I/O:
//! wire frames, timer expiries and closed control channels arrive as
//! [`CoordIn`] events and every externally visible effect leaves as a
//! [`CoordOut`].  The TCP shell in [`crate::transport::elastic`] and
//! the deterministic simulator in [`super::sim`] drive the same
//! machine, which is what makes the simulator's verdicts transfer to
//! the deployed fleet.
//!
//! One machine covers both fleet shapes: `stages == 1` is the
//! single-vector DP fleet (keys are `(rank, 0)`), `stages > 1` the
//! pipeline-stage fleet with `(cluster, stage)` keys, whole-cluster
//! pruning, per-stage drain decisions and finishing epochs.

use std::collections::{BTreeMap, BTreeSet};

use super::{drain_decision, Key};

/// Everything the outside world can tell the coordinator machine.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CoordIn {
    /// Kick off the first epoch (all members registered).
    Start,
    /// A (re)connecting worker announced itself.  Membership is fixed
    /// at registration time, so a Hello from a stale generation is
    /// deliberately inert — the machine ignores it.
    Hello { key: Key },
    /// 2PC ack for a proposed epoch.
    PrepareAck { key: Key, epoch: u32 },
    /// A member's ring failed; it reports how far it got so the fleet
    /// can pick the resume round and rule drain-vs-discard.
    RingBroken { key: Key, applied_rounds: u32, in_flight_round: u32 },
    /// Per-round progress report (drives resume-round bookkeeping).
    Heartbeat { key: Key, round: u32 },
    /// A member completed all of its rounds.
    Done { key: Key },
    /// Failure detector: the member's control channel is gone.  The
    /// shell orders this after everything the member actually sent
    /// (reader-thread EOF semantics), and the simulator preserves that
    /// ordering in its queues.
    Closed { key: Key },
    /// A previously armed timer fired.  Stale tokens (anything but the
    /// most recently armed) are ignored.
    Timer { token: u64 },
}

/// Everything the coordinator machine can ask the outside world to do.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CoordOut {
    /// Propose epoch membership to one member (2PC phase one).  `ring`
    /// is the member's reduce ring for the epoch; `link_down` the next
    /// pipeline stage to dial, if any.
    Prepare {
        to: Key,
        epoch: u32,
        resume_round: u32,
        ring: Vec<Key>,
        link_down: Option<Key>,
        drain_round: u32,
    },
    /// All recipients acked: commit the epoch (2PC phase two).
    Commit { to: Key, epoch: u32 },
    /// Tell a member the run is over (or its cluster was pruned).
    Shutdown { to: Key },
    /// Arm the single coordinator timer with a fresh token; a later
    /// `ArmTimer` supersedes any earlier one.
    ArmTimer { token: u64 },
    /// Record keeping: an epoch committed with this per-stage drain
    /// ruling (0 = discard).  The shell turns these into telemetry.
    Committed { epoch: u32, stage: u32, drain_round: u32 },
    /// Every live member finished its rounds; `Shutdown`s were issued.
    Finished,
    /// No members remain; the run cannot complete.
    Failed { reason: String },
}

#[derive(Clone, Debug)]
enum Phase {
    /// Constructed but not started.
    Idle,
    /// 2PC phase one: waiting for every recipient to ack `epoch`.
    Preparing { recipients: Vec<Key>, drains: Vec<u32>, acked: BTreeSet<Key> },
    /// An epoch is committed and rings are running rounds.
    Running,
    /// Churn detected: waiting (bounded by the grace timer) for every
    /// not-yet-broken member to report in before re-preparing.
    Draining { broken: BTreeSet<Key> },
    Finished,
    Failed,
}

/// What a dispatched input asks the machine to do next.  Computed
/// first, performed second, so phase payloads and the membership sets
/// never need to be borrowed at the same time.
enum Act {
    None,
    StartEpoch,
    Commit,
    Finish,
    EnterDrain(BTreeSet<Key>),
}

/// Pure coordinator machine for the elastic membership protocol.
#[derive(Clone, Debug)]
pub struct CoordinatorSm {
    stages: u32,
    rounds: u32,
    live: BTreeSet<Key>,
    done: BTreeSet<Key>,
    /// Last reported in-flight round per member, cleared on commit —
    /// the input vector of [`drain_decision`].
    inflight: BTreeMap<Key, u32>,
    epoch: u32,
    resume_round: u32,
    timer_token: u64,
    phase: Phase,
    /// Preferred cluster order for the reduce ring (bandwidth-aware
    /// reordering or (site, rank) grouping).  Clusters appear in this
    /// order first; anything unlisted — e.g. a member that joined after
    /// the probe ran — trails in ascending cluster order.  Empty means
    /// the historical ascending order.  A preference only biases the
    /// ring layout shipped in `Prepare`; membership decisions are
    /// untouched, which keeps every model-checked property intact.
    order: Vec<u32>,
    /// Close each cluster's stage-link chain into a ring: the last
    /// executor also links down to stage 0 (interleaved virtual-stage
    /// schedules hand the final model chunk's activations back to the
    /// first executor).  Like `order`, this only shapes the wiring
    /// shipped in `Prepare` — membership decisions are untouched.
    wrap_links: bool,
}

impl CoordinatorSm {
    /// A machine over a registered fleet.  `stages == 1` selects
    /// single-fleet semantics; `rounds` is the configured outer-round
    /// count (used only to detect finishing epochs in stage fleets).
    pub fn new(members: impl IntoIterator<Item = Key>, stages: u32, rounds: u32) -> CoordinatorSm {
        CoordinatorSm {
            stages: stages.max(1),
            rounds,
            live: members.into_iter().collect(),
            done: BTreeSet::new(),
            inflight: BTreeMap::new(),
            epoch: 0,
            resume_round: 1,
            timer_token: 0,
            phase: Phase::Idle,
            order: Vec::new(),
            wrap_links: false,
        }
    }

    /// Close the stage-link chain into a ring for future epochs (the
    /// interleaved virtual-stage topology).  No-op for single fleets.
    pub fn set_wrap_links(&mut self, wrap: bool) {
        self.wrap_links = wrap;
    }

    /// Install a preferred cluster order for future epochs' rings (see
    /// the `order` field).  Takes effect at the next `start_epoch`; an
    /// epoch already in flight keeps the layout it proposed.
    pub fn set_cluster_order(&mut self, order: Vec<u32>) {
        self.order = order;
    }

    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    pub fn resume_round(&self) -> u32 {
        self.resume_round
    }

    pub fn live(&self) -> &BTreeSet<Key> {
        &self.live
    }

    pub fn done(&self) -> &BTreeSet<Key> {
        &self.done
    }

    pub fn is_finished(&self) -> bool {
        matches!(self.phase, Phase::Finished)
    }

    pub fn is_failed(&self) -> bool {
        matches!(self.phase, Phase::Failed)
    }

    pub fn is_terminal(&self) -> bool {
        self.is_finished() || self.is_failed()
    }

    /// Feed one event; returns every effect it causes, in order.
    pub fn handle(&mut self, input: CoordIn) -> Vec<CoordOut> {
        let mut out = Vec::new();
        if self.is_terminal() {
            return out;
        }
        // Stage fleets key everything by (cluster, stage) and prune
        // whole clusters, so traffic from orphaned members of a pruned
        // cluster must not perturb the survivors.  The single fleet
        // keeps the historical behavior of counting progress from any
        // reporter.
        if self.stages > 1 {
            if let Some(k) = input_key(&input) {
                if !self.live.contains(&k) {
                    return out;
                }
            }
        }
        // Progress bookkeeping applies in every phase, exactly like the
        // shell's event loop noted progress on every received frame.
        match &input {
            CoordIn::Heartbeat { round, .. } => {
                self.resume_round = self.resume_round.max(round + 1);
            }
            CoordIn::RingBroken { key, applied_rounds, in_flight_round } => {
                self.resume_round = self.resume_round.max(applied_rounds + 1);
                self.inflight.insert(*key, *in_flight_round);
            }
            CoordIn::Done { key } => {
                self.done.insert(*key);
            }
            _ => {}
        }
        let act = self.dispatch(&input);
        match act {
            Act::None => {}
            Act::StartEpoch => self.start_epoch(&mut out),
            Act::Commit => self.commit(&mut out),
            Act::Finish => self.finish(&mut out),
            Act::EnterDrain(broken) => self.enter_drain(broken, &mut out),
        }
        out
    }

    fn dispatch(&mut self, input: &CoordIn) -> Act {
        match &mut self.phase {
            Phase::Idle => match input {
                CoordIn::Start => Act::StartEpoch,
                _ => Act::None,
            },
            Phase::Preparing { recipients, acked, .. } => match input {
                CoordIn::PrepareAck { key, epoch } if *epoch == self.epoch => {
                    acked.insert(*key);
                    ready_act(recipients, acked, &self.done, &self.live)
                }
                CoordIn::Done { .. } => ready_act(recipients, acked, &self.done, &self.live),
                CoordIn::Closed { key } => {
                    if self.live.contains(key) && !self.done.contains(key) {
                        self.live.remove(key);
                        Act::StartEpoch
                    } else {
                        Act::None
                    }
                }
                CoordIn::Timer { token } if *token == self.timer_token => Act::StartEpoch,
                _ => Act::None,
            },
            Phase::Running => match input {
                CoordIn::Done { .. } => {
                    if self.all_live_done() {
                        Act::Finish
                    } else {
                        Act::None
                    }
                }
                CoordIn::RingBroken { key, .. } => Act::EnterDrain(BTreeSet::from([*key])),
                CoordIn::Closed { key } => {
                    if self.done.contains(key) {
                        Act::None
                    } else {
                        self.live.remove(key);
                        if self.all_live_done() {
                            Act::Finish
                        } else {
                            Act::EnterDrain(BTreeSet::new())
                        }
                    }
                }
                _ => Act::None,
            },
            Phase::Draining { broken } => match input {
                CoordIn::RingBroken { key, .. } => {
                    broken.insert(*key);
                    drained_act(&self.live, &self.done, broken)
                }
                CoordIn::Done { .. } => drained_act(&self.live, &self.done, broken),
                CoordIn::Closed { key } => {
                    if !self.done.contains(key) {
                        self.live.remove(key);
                    }
                    drained_act(&self.live, &self.done, broken)
                }
                CoordIn::Timer { token } if *token == self.timer_token => Act::StartEpoch,
                _ => Act::None,
            },
            Phase::Finished | Phase::Failed => Act::None,
        }
    }

    fn all_live_done(&self) -> bool {
        self.live.iter().all(|k| self.done.contains(k))
    }

    /// Open the next 2PC generation: prune, pick recipients, rule
    /// drain-vs-discard per stage, and send `Prepare`s.
    fn start_epoch(&mut self, out: &mut Vec<CoordOut>) {
        self.timer_token += 1; // any armed timer is now stale
        if self.stages > 1 {
            self.prune_partial_clusters(out);
        }
        if self.live.is_empty() {
            let reason = if self.stages > 1 { "all clusters died" } else { "all workers died" };
            self.phase = Phase::Failed;
            out.push(CoordOut::Failed { reason: reason.to_string() });
            return;
        }
        let clusters: BTreeSet<u32> = self.live.iter().map(|&(c, _)| c).collect();
        let mut pending: Vec<u32> = clusters
            .into_iter()
            .filter(|&c| (0..self.stages).any(|s| !self.done.contains(&(c, s))))
            .collect();
        if !self.order.is_empty() {
            let pos =
                |c: u32| self.order.iter().position(|&o| o == c).unwrap_or(usize::MAX);
            pending.sort_by_key(|&c| (pos(c), c));
        }
        if pending.is_empty() {
            self.finish(out);
            return;
        }
        self.epoch += 1;
        let recipients: Vec<Key> = pending
            .iter()
            .flat_map(|&c| (0..self.stages).map(move |s| (c, s)))
            .filter(|k| !self.done.contains(k))
            .collect();
        let drains: Vec<u32> = (0..self.stages)
            .map(|s| {
                drain_decision(
                    recipients
                        .iter()
                        .filter(|&&(_, s2)| s2 == s)
                        .map(|k| self.inflight.get(k).copied()),
                )
            })
            .collect();
        for &d in &drains {
            if d > 0 {
                self.resume_round = self.resume_round.max(d + 1);
            }
        }
        // A finishing epoch (stage fleets only): every remaining round
        // is already applied, the fleet only has trailing drains and
        // Done reports left.  Stages with no drain pending form solo
        // rings so nobody waits on a peer with nothing to reduce.
        let finishing = self.stages > 1 && self.resume_round > self.rounds;
        for &(c, s) in &recipients {
            let d = drains[s as usize];
            let ring: Vec<Key> = if finishing && d == 0 {
                vec![(c, s)]
            } else {
                pending
                    .iter()
                    .filter(|&&c2| !self.done.contains(&(c2, s)))
                    .map(|&c2| (c2, s))
                    .collect()
            };
            let next_s = if self.wrap_links { (s + 1) % self.stages } else { s + 1 };
            let link_down = if self.stages > 1
                && !finishing
                && next_s < self.stages
                && next_s != s
                && !self.done.contains(&(c, next_s))
            {
                Some((c, next_s))
            } else {
                None
            };
            out.push(CoordOut::Prepare {
                to: (c, s),
                epoch: self.epoch,
                resume_round: self.resume_round,
                ring,
                link_down,
                drain_round: d,
            });
        }
        out.push(CoordOut::ArmTimer { token: self.timer_token });
        self.phase = Phase::Preparing { recipients, drains, acked: BTreeSet::new() };
    }

    /// Drop clusters that lost any stage; their surviving members get a
    /// `Shutdown` (they cannot contribute a partial pipeline).
    fn prune_partial_clusters(&mut self, out: &mut Vec<CoordOut>) {
        let clusters: BTreeSet<u32> = self.live.iter().map(|&(c, _)| c).collect();
        for c in clusters {
            if (0..self.stages).all(|s| self.live.contains(&(c, s))) {
                continue;
            }
            for s in 0..self.stages {
                if self.live.remove(&(c, s)) {
                    out.push(CoordOut::Shutdown { to: (c, s) });
                }
            }
        }
    }

    /// 2PC phase two: every recipient acked and none went stale.
    fn commit(&mut self, out: &mut Vec<CoordOut>) {
        let prev = std::mem::replace(&mut self.phase, Phase::Running);
        let Phase::Preparing { recipients, drains, .. } = prev else {
            unreachable!("commit outside of Preparing");
        };
        for &k in &recipients {
            out.push(CoordOut::Commit { to: k, epoch: self.epoch });
        }
        for (s, &d) in drains.iter().enumerate() {
            out.push(CoordOut::Committed { epoch: self.epoch, stage: s as u32, drain_round: d });
        }
        // The committed decision consumed these reports; the next
        // ruling must come from fresh RingBroken evidence.
        for k in &recipients {
            self.inflight.remove(k);
        }
    }

    /// Churn observed while running: collect reports from everyone not
    /// yet accounted for (bounded by the grace timer), then re-prepare.
    fn enter_drain(&mut self, broken: BTreeSet<Key>, out: &mut Vec<CoordOut>) {
        if outstanding(&self.live, &self.done, &broken) == 0 {
            self.start_epoch(out);
        } else {
            self.timer_token += 1;
            out.push(CoordOut::ArmTimer { token: self.timer_token });
            self.phase = Phase::Draining { broken };
        }
    }

    fn finish(&mut self, out: &mut Vec<CoordOut>) {
        for &k in &self.live {
            out.push(CoordOut::Shutdown { to: k });
        }
        self.phase = Phase::Finished;
        out.push(CoordOut::Finished);
    }
}

/// The member a received event is attributed to, if any.
fn input_key(input: &CoordIn) -> Option<Key> {
    match input {
        CoordIn::Hello { key }
        | CoordIn::PrepareAck { key, .. }
        | CoordIn::RingBroken { key, .. }
        | CoordIn::Heartbeat { key, .. }
        | CoordIn::Done { key }
        | CoordIn::Closed { key } => Some(*key),
        CoordIn::Start | CoordIn::Timer { .. } => None,
    }
}

/// Ack-wait resolution: once every recipient is accounted for, commit —
/// unless any recipient finished or vanished mid-prepare, which makes
/// the proposal stale and forces a fresh epoch.
fn ready_act(
    recipients: &[Key],
    acked: &BTreeSet<Key>,
    done: &BTreeSet<Key>,
    live: &BTreeSet<Key>,
) -> Act {
    let ready = recipients
        .iter()
        .all(|k| acked.contains(k) || done.contains(k) || !live.contains(k));
    if !ready {
        return Act::None;
    }
    if recipients.iter().any(|k| done.contains(k) || !live.contains(k)) {
        Act::StartEpoch
    } else {
        Act::Commit
    }
}

fn outstanding(live: &BTreeSet<Key>, done: &BTreeSet<Key>, broken: &BTreeSet<Key>) -> usize {
    live.iter().filter(|k| !done.contains(k) && !broken.contains(k)).count()
}

/// Re-prepare as soon as every live member is done or accounted broken.
fn drained_act(live: &BTreeSet<Key>, done: &BTreeSet<Key>, broken: &BTreeSet<Key>) -> Act {
    if outstanding(live, done, broken) == 0 {
        Act::StartEpoch
    } else {
        Act::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(ranks: &[u32]) -> Vec<Key> {
        ranks.iter().map(|&r| (r, 0)).collect()
    }

    fn start(sm: &mut CoordinatorSm) -> Vec<CoordOut> {
        sm.handle(CoordIn::Start)
    }

    fn prepares(out: &[CoordOut]) -> Vec<Key> {
        out.iter()
            .filter_map(|o| match o {
                CoordOut::Prepare { to, .. } => Some(*to),
                _ => None,
            })
            .collect()
    }

    fn commits(out: &[CoordOut]) -> Vec<Key> {
        out.iter()
            .filter_map(|o| match o {
                CoordOut::Commit { to, .. } => Some(*to),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn happy_path_commits_then_finishes() {
        let mut sm = CoordinatorSm::new(keys(&[0, 1, 2]), 1, 4);
        let out = start(&mut sm);
        assert_eq!(prepares(&out), keys(&[0, 1, 2]));
        assert_eq!(sm.epoch(), 1);
        // Two acks: not ready yet.
        assert!(sm.handle(CoordIn::PrepareAck { key: (0, 0), epoch: 1 }).is_empty());
        assert!(sm.handle(CoordIn::PrepareAck { key: (1, 0), epoch: 1 }).is_empty());
        // Third ack commits.
        let out = sm.handle(CoordIn::PrepareAck { key: (2, 0), epoch: 1 });
        assert_eq!(commits(&out), keys(&[0, 1, 2]));
        assert!(out
            .iter()
            .any(|o| matches!(o, CoordOut::Committed { epoch: 1, stage: 0, drain_round: 0 })));
        // All done → shutdown + finished.
        assert!(sm.handle(CoordIn::Done { key: (0, 0) }).is_empty());
        assert!(sm.handle(CoordIn::Done { key: (1, 0) }).is_empty());
        let out = sm.handle(CoordIn::Done { key: (2, 0) });
        assert_eq!(out.iter().filter(|o| matches!(o, CoordOut::Shutdown { .. })).count(), 3);
        assert!(matches!(out.last(), Some(CoordOut::Finished)));
        assert!(sm.is_finished());
    }

    fn ring_of(out: &[CoordOut], who: Key) -> Vec<Key> {
        out.iter()
            .find_map(|o| match o {
                CoordOut::Prepare { to, ring, .. } if *to == who => Some(ring.clone()),
                _ => None,
            })
            .unwrap()
    }

    /// A cluster-order preference reshapes the proposed ring without
    /// touching membership; unlisted clusters trail in ascending order.
    #[test]
    fn cluster_order_preference_reshapes_the_ring() {
        let mut sm = CoordinatorSm::new(keys(&[0, 1, 2, 3]), 1, 4);
        sm.set_cluster_order(vec![0, 2, 1, 3]);
        let out = start(&mut sm);
        assert_eq!(ring_of(&out, (1, 0)), keys(&[0, 2, 1, 3]));
        // Same recipients either way — only the layout moved.
        let mut got = prepares(&out);
        got.sort();
        assert_eq!(got, keys(&[0, 1, 2, 3]));
        // A member missing from the preference (here: everyone after a
        // preference set pre-churn) trails in ascending order.
        let mut sm = CoordinatorSm::new(keys(&[0, 1, 2, 3]), 1, 4);
        sm.set_cluster_order(vec![3, 1]);
        let out = start(&mut sm);
        assert_eq!(ring_of(&out, (0, 0)), keys(&[3, 1, 0, 2]));
    }

    /// The default (empty) preference keeps the historical ascending
    /// ring, and a preference composes with churn: the re-prepared ring
    /// keeps the survivors in preference order.
    #[test]
    fn cluster_order_survives_churn() {
        let mut sm = CoordinatorSm::new(keys(&[0, 1, 2]), 1, 4);
        let out = start(&mut sm);
        assert_eq!(ring_of(&out, (0, 0)), keys(&[0, 1, 2]), "default stays ascending");
        let mut sm = CoordinatorSm::new(keys(&[0, 1, 2]), 1, 4);
        sm.set_cluster_order(vec![2, 0, 1]);
        start(&mut sm);
        for r in 0..3 {
            sm.handle(CoordIn::PrepareAck { key: (r, 0), epoch: 1 });
        }
        // Worker 0 dies mid-run → fresh epoch over the survivors, still
        // laid out by the preference.
        let out = sm.handle(CoordIn::Closed { key: (0, 0) });
        assert_eq!(sm.epoch(), 2);
        assert_eq!(ring_of(&out, (1, 0)), keys(&[2, 1]));
    }

    /// Satellite edge case: a worker dies *between* its PrepareAck and
    /// the Commit.  The proposal must be superseded by a fresh epoch
    /// that excludes the dead member — never committed as-is.
    #[test]
    fn death_between_ack_and_commit_supersedes_epoch() {
        let mut sm = CoordinatorSm::new(keys(&[0, 1]), 1, 4);
        start(&mut sm);
        assert!(sm.handle(CoordIn::PrepareAck { key: (0, 0), epoch: 1 }).is_empty());
        // Worker 0 dies before worker 1's ack lands.
        let out = sm.handle(CoordIn::Closed { key: (0, 0) });
        assert!(commits(&out).is_empty(), "must not commit a dead member");
        assert_eq!(sm.epoch(), 2, "proposal superseded");
        assert_eq!(prepares(&out), keys(&[1]));
        // The stale ack for epoch 1 is ignored; the fresh one commits.
        assert!(sm.handle(CoordIn::PrepareAck { key: (1, 0), epoch: 1 }).is_empty());
        let out = sm.handle(CoordIn::PrepareAck { key: (1, 0), epoch: 2 });
        assert_eq!(commits(&out), keys(&[1]));
    }

    /// Satellite edge case: the ack arrives, then the member's channel
    /// closes moments before Commit would have been sent (i.e. the ack
    /// completed the wait but a Done/closure made the proposal stale).
    #[test]
    fn recipient_finishing_mid_prepare_forces_fresh_epoch() {
        let mut sm = CoordinatorSm::new(keys(&[0, 1]), 1, 4);
        start(&mut sm);
        assert!(sm.handle(CoordIn::PrepareAck { key: (0, 0), epoch: 1 }).is_empty());
        // Worker 1 reports Done instead of acking: the wait completes
        // but the membership proposal is stale → re-prepare without it.
        let out = sm.handle(CoordIn::Done { key: (1, 0) });
        assert!(commits(&out).is_empty());
        assert_eq!(sm.epoch(), 2);
        assert_eq!(prepares(&out), keys(&[0]));
    }

    /// Satellite edge case: a Hello from a stale generation (a worker
    /// re-announcing itself after churn) is inert — no outputs, no
    /// state change.
    #[test]
    fn stale_hello_is_ignored() {
        let mut sm = CoordinatorSm::new(keys(&[0, 1]), 1, 4);
        start(&mut sm);
        let before_epoch = sm.epoch();
        assert!(sm.handle(CoordIn::Hello { key: (0, 0) }).is_empty());
        assert!(sm.handle(CoordIn::Hello { key: (9, 0) }).is_empty());
        assert_eq!(sm.epoch(), before_epoch);
        assert_eq!(sm.live().len(), 2);
    }

    #[test]
    fn ack_timeout_reprepares() {
        let mut sm = CoordinatorSm::new(keys(&[0, 1]), 1, 4);
        let out = start(&mut sm);
        let token = out
            .iter()
            .find_map(|o| match o {
                CoordOut::ArmTimer { token } => Some(*token),
                _ => None,
            })
            .unwrap();
        assert!(sm.handle(CoordIn::PrepareAck { key: (0, 0), epoch: 1 }).is_empty());
        // Stale token: ignored.
        assert!(sm.handle(CoordIn::Timer { token: token + 99 }).is_empty());
        // Live token: re-prepare with a fresh epoch.
        let out = sm.handle(CoordIn::Timer { token });
        assert_eq!(sm.epoch(), 2);
        assert_eq!(prepares(&out), keys(&[0, 1]));
    }

    #[test]
    fn unanimous_break_drains_and_bumps_resume() {
        let mut sm = CoordinatorSm::new(keys(&[0, 1]), 1, 8);
        start(&mut sm);
        sm.handle(CoordIn::PrepareAck { key: (0, 0), epoch: 1 });
        sm.handle(CoordIn::PrepareAck { key: (1, 0), epoch: 1 });
        // Both report the same in-flight round 3 with 2 applied.
        let out = sm.handle(CoordIn::RingBroken {
            key: (0, 0),
            applied_rounds: 2,
            in_flight_round: 3,
        });
        assert!(prepares(&out).is_empty(), "waits for the second report");
        let out = sm.handle(CoordIn::RingBroken {
            key: (1, 0),
            applied_rounds: 2,
            in_flight_round: 3,
        });
        assert_eq!(sm.epoch(), 2);
        let drain = out
            .iter()
            .find_map(|o| match o {
                CoordOut::Prepare { drain_round, resume_round, .. } => {
                    Some((*drain_round, *resume_round))
                }
                _ => None,
            })
            .unwrap();
        assert_eq!(drain, (3, 4), "drain round 3, resume past it");
    }

    #[test]
    fn mixed_reports_discard() {
        let mut sm = CoordinatorSm::new(keys(&[0, 1]), 1, 8);
        start(&mut sm);
        sm.handle(CoordIn::PrepareAck { key: (0, 0), epoch: 1 });
        sm.handle(CoordIn::PrepareAck { key: (1, 0), epoch: 1 });
        sm.handle(CoordIn::RingBroken { key: (0, 0), applied_rounds: 2, in_flight_round: 3 });
        let out =
            sm.handle(CoordIn::RingBroken { key: (1, 0), applied_rounds: 3, in_flight_round: 4 });
        let drain = out
            .iter()
            .find_map(|o| match o {
                CoordOut::Prepare { drain_round, .. } => Some(*drain_round),
                _ => None,
            })
            .unwrap();
        assert_eq!(drain, 0, "disagreement must discard");
        assert_eq!(sm.resume_round(), 4, "resume from max applied + 1");
    }

    #[test]
    fn all_members_lost_fails() {
        let mut sm = CoordinatorSm::new(keys(&[0]), 1, 4);
        start(&mut sm);
        let out = sm.handle(CoordIn::Closed { key: (0, 0) });
        assert!(out
            .iter()
            .any(|o| matches!(o, CoordOut::Failed { reason } if reason == "all workers died")));
        assert!(sm.is_failed());
        // Terminal: further inputs are inert.
        assert!(sm.handle(CoordIn::Start).is_empty());
    }

    #[test]
    fn stage_fleet_prunes_partial_clusters() {
        // Two clusters × two stages; cluster 1 loses stage 0.
        let members = vec![(0, 0), (0, 1), (1, 0), (1, 1)];
        let mut sm = CoordinatorSm::new(members, 2, 4);
        let out = start(&mut sm);
        assert_eq!(prepares(&out).len(), 4);
        for k in [(0, 0), (0, 1), (1, 1)] {
            sm.handle(CoordIn::PrepareAck { key: k, epoch: 1 });
        }
        let out = sm.handle(CoordIn::Closed { key: (1, 0) });
        // The fresh epoch prunes the whole cluster 1: its surviving
        // stage gets a Shutdown, and the new rings only span cluster 0.
        assert!(out
            .iter()
            .any(|o| matches!(o, CoordOut::Shutdown { to } if *to == (1, 1))));
        assert_eq!(prepares(&out), vec![(0, 0), (0, 1)]);
        assert!(!sm.live().contains(&(1, 1)));
        // Events from the orphan are now filtered.
        assert!(sm
            .handle(CoordIn::RingBroken { key: (1, 1), applied_rounds: 9, in_flight_round: 9 })
            .is_empty());
        assert_eq!(sm.resume_round(), 1, "orphan report must not bump resume");
    }

    #[test]
    fn stage_fleet_finishing_epoch_solo_rings_and_link_teardown() {
        let mut sm = CoordinatorSm::new(vec![(0, 0), (0, 1)], 2, 2);
        let out = start(&mut sm);
        // Initially stage 0 links down to stage 1.
        let link = out
            .iter()
            .find_map(|o| match o {
                CoordOut::Prepare { to: (0, 0), link_down, .. } => Some(*link_down),
                _ => None,
            })
            .unwrap();
        assert_eq!(link, Some((0, 1)));
        sm.handle(CoordIn::PrepareAck { key: (0, 0), epoch: 1 });
        sm.handle(CoordIn::PrepareAck { key: (0, 1), epoch: 1 });
        // Stage 1 finishes round 2 then stage 0 breaks holding round 2
        // in flight: resume (3) > rounds (2) → a finishing epoch.
        sm.handle(CoordIn::Heartbeat { key: (0, 1), round: 2 });
        let out =
            sm.handle(CoordIn::RingBroken { key: (0, 0), applied_rounds: 1, in_flight_round: 2 });
        // Only the broken stage is outstanding… the other one is still
        // running, so the coordinator drains first.
        let out = if prepares(&out).is_empty() {
            sm.handle(CoordIn::Done { key: (0, 1) })
        } else {
            out
        };
        assert_eq!(sm.epoch(), 2);
        // Stage 0 holds a unanimous in-flight round 2 → drain ring; the
        // link to the finished stage below must be torn down.
        let (ring, link, drain) = out
            .iter()
            .find_map(|o| match o {
                CoordOut::Prepare { to: (0, 0), ring, link_down, drain_round, .. } => {
                    Some((ring.clone(), *link_down, *drain_round))
                }
                _ => None,
            })
            .unwrap();
        assert_eq!(ring, vec![(0, 0)]);
        assert_eq!(link, None, "finishing epoch must not dial the done stage");
        assert_eq!(drain, 2);
    }
}
