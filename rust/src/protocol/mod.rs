//! Pure, I/O-free elastic membership protocol: the 2PC epoch/drain core
//! as explicit state machines.
//!
//! This module is the *correctness core* of the elastic fleet, factored
//! out of [`crate::transport::elastic`] so it can be exhaustively
//! verified: [`coordinator::CoordinatorSm`] and [`worker::WorkerSm`] are
//! plain `fn handle(&mut self, input) -> Vec<output>` machines with no
//! sockets, no threads, and no clocks — timers and failure detection
//! arrive as explicit inputs.  The TCP shell in `transport::elastic`
//! feeds wire frames into the same machines that the deterministic
//! simulation harness ([`sim`]) drives over virtual time, so every
//! interleaving the simulator explores is an execution the deployed
//! fleet could take.
//!
//! # Coordinator state diagram
//!
//! ```text
//!             Start/-re-prepare-------------------------------.
//!               v                                             |
//!  +-----------------+  all recipients acked  +-----------+   |
//!  |    Preparing    |----------------------->|  Running  |   |
//!  | (2PC prepare,   |   (send Commit, log    | (epoch    |   |
//!  |  ack collection)|    drain decision)     |  committed)|  |
//!  +-----------------+                        +-----------+   |
//!    |  ^    | ack timer fired,                  |    |       |
//!    |  |    | member closed,                    |    | churn |
//!    |  |    | member done        all live done  |    v       |
//!    |  |    '----------------.   (Shutdown)     | +----------+
//!    |  '---------------------|------------------+ | Draining |
//!    |     re-prepare         v                    | (collect |
//!    |                    [Finished]               |  breaks) |
//!    '--- no member left → [Failed]                +----------+
//!                                             grace timer / all broken
//!                                                  → re-prepare
//! ```
//!
//! Every epoch is one 2PC generation: `Prepare{epoch, members,
//! resume_round, drain_round}` → unanimous `PrepareAck{epoch}` →
//! `Commit{epoch}`.  Any membership change observed mid-prepare (a
//! closed control channel, a member finishing) supersedes the proposal
//! with a fresh epoch, so **at most one membership is ever committed per
//! epoch number** — the first safety invariant the simulator asserts.
//!
//! # Worker state diagram
//!
//! ```text
//!   Waiting --Prepare(e>committed)/ack--> Waiting(prepared=e)
//!   Waiting --Commit(prepared)----------> Forming   (shell dials ring)
//!   Forming --ok--------> Beginning  (consensus resync + recovery)
//!   Forming --fail------> Waiting    (report RingBroken)
//!   Beginning --ok------> Running    (rounds resume_round..=T)
//!   Beginning --fail----> Waiting    (report RingBroken)
//!   Running --completed-> Finishing  (trailing in-flight drain)
//!   Running --broken----> Waiting    (report RingBroken)
//!   Finishing --ok------> AwaitShutdown (report Done)
//!   Finishing --fail----> Waiting    (report RingBroken)
//!   Waiting/AwaitShutdown --Shutdown--> Exited
//! ```
//!
//! # The drain-unanimity invariant
//!
//! With one-step-delay overlap every worker holds one δ-reduction in
//! flight across each round boundary, so churn catches reductions
//! mid-flight.  The committed `drain_round` of each epoch is computed by
//! [`drain_decision`]: **drain** (finish the held reduction of round t
//! on the re-formed ring, exactly once) only when *every* member of the
//! proposed ring reported the *same* in-flight round t; any
//! disagreement, any member with nothing in flight, or any member that
//! never reported forces **discard** (each survivor folds its delta
//! back into error feedback, where it re-enters the next round's δ
//! exactly once).  A partial drain collective would stall on the
//! members with nothing to reduce, so unanimity is the precondition.
//! The per-worker side of the same arithmetic is [`resume_plan`] —
//! consumed by the real [`crate::rounds::driver::RoundDriver`] and by
//! the simulator's virtual driver, so the two cannot diverge.
//!
//! # How `sim` schedules relate to real transports
//!
//! The harness in [`sim`] replaces every I/O edge with a FIFO queue and
//! every blocking collective with a ring barrier: delivering a queued
//! message, firing an armed timer, completing a ring barrier, and
//! injecting a crash or soft break are *scheduler actions*, and an
//! execution is one interleaving of those actions.  A TCP deployment is
//! one particular schedule (messages arrive in socket order, timers
//! fire when wall-clock grace expires, crashes land wherever the OS
//! lands them); the fuzzer and the bounded exhaustive explorer walk the
//! schedules the wall clock happens not to pick.

pub mod coordinator;
pub mod sim;
pub mod worker;

pub use coordinator::{CoordIn, CoordOut, CoordinatorSm};
pub use worker::{EpochPlan, WorkerIn, WorkerOut, WorkerPhase, WorkerSm};

/// Member identity: `(cluster, stage)`.  The single-vector DP fleet is
/// the degenerate `stage = 0` case.
pub type Key = (u32, u32);

/// The committed per-ring recovery decision carried by
/// `Prepare`/`StagePrepare` (see the module docs for the unanimity
/// rule).  Lives here — next to [`drain_decision`], which produces it —
/// and is re-exported by [`crate::rounds::driver`], which consumes it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Recovery {
    /// Fold any in-flight delta into the error buffer (also the benign
    /// epoch-1 case: nothing in flight, nothing to do).
    Discard,
    /// Finish the in-flight reduction of this round on the re-formed
    /// ring and apply its outer update.
    Drain { round: u64 },
}

impl Recovery {
    /// Wire encoding: `drain_round` field of Prepare/StagePrepare
    /// (0 = discard).
    pub fn from_wire(drain_round: u32) -> Recovery {
        if drain_round == 0 {
            Recovery::Discard
        } else {
            Recovery::Drain { round: drain_round as u64 }
        }
    }

    pub fn to_wire(&self) -> u32 {
        match self {
            Recovery::Discard => 0,
            Recovery::Drain { round } => *round as u32,
        }
    }
}

/// The coordinator-side drain-or-discard rule (module docs): drain only
/// when EVERY member of the proposed ring reported the SAME in-flight
/// round; mixed rounds, a `None` (member never reported), a `Some(0)`
/// (member reported nothing in flight), or an empty membership all
/// force discard.  Returns the drain round (0 = discard).
pub fn drain_decision(reported: impl Iterator<Item = Option<u32>>) -> u32 {
    let mut agreed = 0u32;
    let mut any = false;
    for r in reported {
        any = true;
        match r {
            None | Some(0) => return 0,
            Some(v) if agreed == 0 => agreed = v,
            Some(v) if v != agreed => return 0,
            _ => {}
        }
    }
    if any {
        agreed
    } else {
        0
    }
}

/// What a worker must do with its held in-flight delta on entering a
/// committed epoch — the worker-side resume arithmetic, pure so the
/// real driver and the simulator's virtual driver share one copy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResumePlan {
    /// Nothing in flight: plain consensus resync only.
    Nothing,
    /// The committed decision drains our held round: re-reduce it on
    /// the fresh ring and apply its outer update (exactly once).
    Drain { round: u64 },
    /// The abandoned flight COMPLETED before the epoch turned, so the
    /// old ring's peers already applied its mean — apply it exactly
    /// once here too (late join), instead of re-injecting it via the
    /// discard fold.
    LateJoin { round: u64 },
    /// Fold the in-flight delta of this round back into error
    /// feedback, where it re-enters the next round's δ exactly once.
    Discard { round: u64 },
}

/// Compute the [`ResumePlan`] from the committed recovery decision, the
/// round of the delta this worker still holds in flight (if any), and
/// whether the abandoned flight's collective already completed.
///
/// Precedence mirrors the driver's historical behavior: a committed
/// drain *for the round we hold* wins (the re-formed ring must
/// re-reduce collectively, every member present — even if our old
/// flight completed, its mean is dropped in favor of the fresh
/// collective); otherwise a completed flight late-joins; otherwise the
/// held delta is discarded.
pub fn resume_plan(
    recovery: Recovery,
    in_flight: Option<u64>,
    flight_completed: bool,
) -> ResumePlan {
    match in_flight {
        None => ResumePlan::Nothing,
        Some(r) => match recovery {
            Recovery::Drain { round } if round == r => ResumePlan::Drain { round },
            _ if flight_completed => ResumePlan::LateJoin { round: r },
            _ => ResumePlan::Discard { round: r },
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn recovery_wire_roundtrip() {
        assert_eq!(Recovery::from_wire(0), Recovery::Discard);
        assert_eq!(Recovery::from_wire(5), Recovery::Drain { round: 5 });
        assert_eq!(Recovery::Drain { round: 5 }.to_wire(), 5);
        assert_eq!(Recovery::Discard.to_wire(), 0);
    }

    #[test]
    fn drain_decision_hand_cases() {
        assert_eq!(drain_decision([Some(3), Some(3)].into_iter()), 3);
        assert_eq!(drain_decision([Some(3), Some(2)].into_iter()), 0);
        assert_eq!(drain_decision([Some(3), None].into_iter()), 0);
        assert_eq!(drain_decision([Some(0), Some(3)].into_iter()), 0);
        assert_eq!(drain_decision(std::iter::empty()), 0);
        assert_eq!(drain_decision([Some(7)].into_iter()), 7);
    }

    /// Property test over seeded arbitrary report vectors: the decision
    /// is drain(t) iff the vector is non-empty and every entry is
    /// `Some(t)` with t > 0; everything else must discard.
    #[test]
    fn drain_decision_property_unanimity() {
        let mut rng = Pcg32::seed_from(0xd4a1);
        for case in 0..5000 {
            let len = rng.below(6) as usize; // 0..=5 members
            let reports: Vec<Option<u32>> = (0..len)
                .map(|_| match rng.below(4) {
                    0 => None,
                    // Small round domain so unanimity actually occurs.
                    _ => Some(rng.below(4)),
                })
                .collect();
            let got = drain_decision(reports.iter().copied());
            let unanimous = !reports.is_empty()
                && reports[0].is_some_and(|r| r > 0)
                && reports.iter().all(|&x| x == reports[0]);
            let want = if unanimous { reports[0].unwrap() } else { 0 };
            assert_eq!(
                got, want,
                "case {case}: reports {reports:?} → got {got}, want {want}"
            );
        }
    }

    /// Any drain the rule emits is a round some member actually holds
    /// (never invented), and a drain is never emitted alongside a
    /// dissenting member — fuzzing the rule's two safety edges.
    #[test]
    fn drain_decision_property_never_invents_rounds() {
        let mut rng = Pcg32::seed_from(0xfeed);
        for _ in 0..5000 {
            let len = rng.below(8) as usize;
            let reports: Vec<Option<u32>> = (0..len)
                .map(|_| match rng.below(3) {
                    0 => None,
                    _ => Some(rng.below(1000)),
                })
                .collect();
            let d = drain_decision(reports.iter().copied());
            if d > 0 {
                assert!(reports.iter().all(|&x| x == Some(d)), "{reports:?}");
            }
        }
    }

    #[test]
    fn resume_plan_cases() {
        use ResumePlan as P;
        // Nothing in flight → nothing to do, whatever was committed.
        assert_eq!(resume_plan(Recovery::Discard, None, false), P::Nothing);
        assert_eq!(
            resume_plan(Recovery::Drain { round: 3 }, None, false),
            P::Nothing
        );
        // Matching drain wins, even over a completed flight.
        assert_eq!(
            resume_plan(Recovery::Drain { round: 3 }, Some(3), false),
            P::Drain { round: 3 }
        );
        assert_eq!(
            resume_plan(Recovery::Drain { round: 3 }, Some(3), true),
            P::Drain { round: 3 }
        );
        // Mismatched drain degrades to the local cases.
        assert_eq!(
            resume_plan(Recovery::Drain { round: 2 }, Some(3), false),
            P::Discard { round: 3 }
        );
        assert_eq!(
            resume_plan(Recovery::Drain { round: 2 }, Some(3), true),
            P::LateJoin { round: 3 }
        );
        // Discard decision: completed flight late-joins, live one folds.
        assert_eq!(
            resume_plan(Recovery::Discard, Some(5), true),
            P::LateJoin { round: 5 }
        );
        assert_eq!(
            resume_plan(Recovery::Discard, Some(5), false),
            P::Discard { round: 5 }
        );
    }
}
