//! Paper reference values + report formatting shared by the benches: each
//! bench prints "paper vs measured" rows so EXPERIMENTS.md can be filled
//! mechanically.

use crate::metrics::Table;
use crate::sim::SimResult;

/// Paper-reported numbers (hard-coded from the text; the benches print
/// them side-by-side with measured values — we reproduce *shape*, not the
/// authors' testbed).
pub mod paper {
    /// Fig 3(a): OPT-1.3B losses after 4000 steps.
    pub const FIG3A_LOSS: [(&str, f64); 4] = [
        ("AllReduce", 4.06),
        ("DiLoCoX", 4.27),
        ("OpenDiLoCo", 5.37),
        ("CocktailSGD", 5.79),
    ];
    /// Fig 3(b): Qwen1.5-107B losses after 4000 steps.
    pub const FIG3B_LOSS: [(&str, f64); 3] = [
        ("AllReduce", 3.90),
        ("DiLoCoX", 4.20),
        ("CocktailSGD", 5.23),
    ];
    /// Fig 4: throughput (tokens/s).  OpenDiLoCo at 107B = OOM.
    pub const FIG4_1_3B: [(&str, f64); 3] = [
        ("AllReduce", 745.0),
        ("CocktailSGD", 16161.0),
        ("DiLoCoX", 23880.0),
    ];
    pub const FIG4_107B: [(&str, f64); 3] = [
        ("AllReduce", 10.4),
        ("CocktailSGD", 2427.0),
        ("DiLoCoX", 3728.0),
    ];
    /// Table 1: Qwen1.5-107B ablation (loss, tokens/s).
    pub const TABLE1: [(&str, f64, f64); 4] = [
        ("Full DiLoCoX", 4.20, 3728.0),
        ("w/o Overlap", 4.15, 2197.0),
        ("w/o Compression", 4.02, 1168.0),
        ("AllReduce", 3.90, 10.4),
    ];
    /// §2.4.1 worked example.
    pub const COMM_ANALYSIS_GB: f64 = 533.3;
    pub const COMM_ANALYSIS_HOURS: f64 = 1.18;
}

pub fn fmt_tps(v: f64) -> String {
    if v >= 100.0 {
        format!("{:.0}", v)
    } else {
        format!("{:.1}", v)
    }
}

/// Render a Fig4-style table: paper value next to simulated value.
pub fn figure4_table(
    scale_name: &str,
    paper_rows: &[(&str, f64)],
    sim: &[SimResult],
) -> String {
    let mut t = Table::new(&[
        "Algorithm",
        "paper tok/s",
        "sim tok/s",
        "sim/paper",
        "sync wire",
        "sync secs",
        "GPU util",
    ]);
    for r in sim {
        let name = r.algo.name();
        let paper = paper_rows
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| *v);
        if r.oom {
            t.row(&[
                name.to_string(),
                "OOM".into(),
                "OOM".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
            continue;
        }
        t.row(&[
            name.to_string(),
            paper.map(fmt_tps).unwrap_or_else(|| "n/a".into()),
            fmt_tps(r.tokens_per_sec),
            paper
                .map(|p| format!("{:.2}x", r.tokens_per_sec / p))
                .unwrap_or_else(|| "-".into()),
            crate::util::fmt_bytes(r.wire_bytes),
            crate::util::fmt_secs(r.comm_secs),
            format!("{:.0}%", 100.0 * r.gpu_utilization),
        ]);
    }
    format!("Figure 4 — {scale_name}\n{}", t.render())
}

/// Relative deviation |a-b| / b.
pub fn rel_dev(measured: f64, paper: f64) -> f64 {
    (measured - paper).abs() / paper.abs().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algo;
    use crate::sim::{figure4_row, ScaleConfig};

    #[test]
    fn paper_constants_sane() {
        assert_eq!(paper::TABLE1.len(), 4);
        let speedup = paper::FIG4_107B[2].1 / paper::FIG4_107B[0].1;
        assert!((speedup - 358.5).abs() < 2.0); // the "357x" headline
    }

    #[test]
    fn figure4_table_renders_with_oom_row() {
        let scale = ScaleConfig::qwen_107b();
        let rows = figure4_row(&scale, 4);
        let s = figure4_table(&scale.name, &paper::FIG4_107B, &rows);
        assert!(s.contains("OOM")); // OpenDiLoCo
        assert!(s.contains("DiLoCoX"));
        assert!(s.contains("paper tok/s"));
        let _ = rows
            .iter()
            .find(|r| r.algo == Algo::DiLoCoX)
            .unwrap();
    }

    #[test]
    fn rel_dev_basics() {
        assert!((rel_dev(110.0, 100.0) - 0.1).abs() < 1e-12);
        assert_eq!(rel_dev(5.0, 5.0), 0.0);
    }
}
