//! Dual Optimizer Policy (paper §2.2): every worker holds a fraction of
//! *both* optimizers — the inner AdamW driving the H local steps and the
//! outer Nesterov applying averaged pseudo-gradients.
//!
//! Host implementations mirror the exported HLO programs bit-for-bit in
//! algebra (see python/compile/model.py adamw_step / nesterov_step); the
//! integration suite cross-checks them against the `adamw_single` /
//! `nesterov_single` artifacts.  The trainer uses the host path on the hot
//! loop (no Literal round-trip) and the HLO path in composition tests.

pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// Inner optimizer state (AdamW) over a flat parameter vector.
#[derive(Clone, Debug)]
pub struct AdamW {
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u64,
    pub lr: f32,
    pub weight_decay: f32,
}

impl AdamW {
    pub fn new(n: usize, lr: f32, weight_decay: f32) -> Self {
        AdamW { m: vec![0.0; n], v: vec![0.0; n], t: 0, lr, weight_decay }
    }

    /// One AdamW step: updates params in place.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let t = self.t as f32;
        let bc1 = 1.0 - ADAM_B1.powf(t);
        let bc2 = 1.0 - ADAM_B2.powf(t);
        let lr = self.lr;
        let wd = self.weight_decay;
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = ADAM_B1 * self.m[i] + (1.0 - ADAM_B1) * g;
            self.v[i] = ADAM_B2 * self.v[i] + (1.0 - ADAM_B2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= lr * (mhat / (vhat.sqrt() + ADAM_EPS) + wd * params[i]);
        }
    }

    /// Reset step count and moments (outer-step boundary policies that
    /// restart inner state — not used by default, exposed for ablations).
    pub fn reset(&mut self) {
        self.m.iter_mut().for_each(|x| *x = 0.0);
        self.v.iter_mut().for_each(|x| *x = 0.0);
        self.t = 0;
    }
}

/// Outer optimizer (SGD with Nesterov momentum, DiLoCo convention):
/// delta = θ_old − θ_new (averaged pseudo-gradient).
#[derive(Clone, Debug)]
pub struct Nesterov {
    pub buf: Vec<f32>,
    pub lr: f32,
    pub momentum: f32,
}

impl Nesterov {
    pub fn new(n: usize, lr: f32, momentum: f32) -> Self {
        Nesterov { buf: vec![0.0; n], lr, momentum }
    }

    pub fn step(&mut self, params: &mut [f32], delta: &[f32]) {
        assert_eq!(params.len(), self.buf.len());
        assert_eq!(delta.len(), self.buf.len());
        let mu = self.momentum;
        let lr = self.lr;
        for i in 0..params.len() {
            self.buf[i] = mu * self.buf[i] + delta[i];
            params[i] -= lr * (delta[i] + mu * self.buf[i]);
        }
    }
}

/// The paper's per-worker optimizer pair.
#[derive(Clone, Debug)]
pub struct DualOptimizer {
    pub inner: AdamW,
    pub outer: Nesterov,
}

impl DualOptimizer {
    pub fn new(
        n: usize,
        inner_lr: f32,
        weight_decay: f32,
        outer_lr: f32,
        outer_momentum: f32,
    ) -> Self {
        DualOptimizer {
            inner: AdamW::new(n, inner_lr, weight_decay),
            outer: Nesterov::new(n, outer_lr, outer_momentum),
        }
    }

    /// Bytes of optimizer state this worker holds — the §2.2 VRAM
    /// balance argument (AdamW m+v plus the outer momentum buffer).
    pub fn state_bytes(&self) -> u64 {
        4 * (self.inner.m.len() + self.inner.v.len() + self.outer.buf.len())
            as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adamw_first_step_is_signed_lr() {
        // t=1, zero state: mhat/(sqrt(vhat)+eps) == sign(g).
        let mut p = vec![0.0f32; 4];
        let g = vec![1.0f32, -1.0, 2.0, 0.0];
        let mut opt = AdamW::new(4, 0.1, 0.0);
        opt.step(&mut p, &g);
        let want = [-0.1f32, 0.1, -0.1, 0.0];
        for (a, b) in p.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn adamw_weight_decay_shrinks_params() {
        let mut p = vec![1.0f32; 8];
        let g = vec![0.0f32; 8];
        let mut opt = AdamW::new(8, 0.01, 0.1);
        opt.step(&mut p, &g);
        assert!(p.iter().all(|&x| x < 1.0 && x > 0.99));
    }

    #[test]
    fn nesterov_matches_python_reference_algebra() {
        // Mirrors test_optim.py::test_nesterov_momentum_accumulates.
        let mut p = vec![0.0f32; 8];
        let delta = vec![1.0f32; 8];
        let mut opt = Nesterov::new(8, 1.0, 0.9);
        opt.step(&mut p, &delta);
        assert!(p.iter().all(|&x| (x + 1.9).abs() < 1e-6));
        opt.step(&mut p, &delta);
        assert!(p.iter().all(|&x| (x + 4.61).abs() < 1e-5), "{p:?}");
    }

    #[test]
    fn adamw_converges_on_quadratic() {
        // min (x - 3)^2 — AdamW should get close in a few hundred steps.
        let mut p = vec![0.0f32];
        let mut opt = AdamW::new(1, 0.05, 0.0);
        for _ in 0..400 {
            let g = vec![2.0 * (p[0] - 3.0)];
            opt.step(&mut p, &g);
        }
        assert!((p[0] - 3.0).abs() < 0.05, "p={}", p[0]);
    }

    #[test]
    fn dual_optimizer_state_accounting() {
        let d = DualOptimizer::new(1000, 1e-3, 0.0, 0.7, 0.9);
        assert_eq!(d.state_bytes(), 4 * 3000);
    }

    #[test]
    fn reset_clears_moments() {
        let mut opt = AdamW::new(2, 0.1, 0.0);
        let mut p = vec![1.0f32, 2.0];
        opt.step(&mut p, &[1.0, 1.0]);
        assert!(opt.t == 1 && opt.m[0] != 0.0);
        opt.reset();
        assert!(opt.t == 0 && opt.m[0] == 0.0 && opt.v[1] == 0.0);
    }
}
