//! dilocox — launcher CLI for the DiLoCoX reproduction.
//!
//! Subcommands:
//!   train        run a real-numerics experiment (single-process trainer)
//!   coordinate   run the coordinator (threaded local ring, or elastic
//!                multi-process TCP ring with --transport tcp; with
//!                --pp > 1 the TCP fleet runs one OS process per
//!                (cluster, stage) with per-stage rings)
//!   worker       one elastic TCP worker process (spawned by `coordinate`;
//!                --stage/--stages make it a stage-fleet member)
//!   simulate     DES throughput at paper scale (Fig 4 / Table 1)
//!   analyze      §2.4.1 communication-overhead analysis
//!   inspect      print an artifact bundle's manifest summary
//!   trace-check  validate a `coordinate --trace` export (schema,
//!                span nesting, round monotonicity, recovery spans)
//!   protocol-verify  model-check the elastic membership protocol: the
//!                bounded exhaustive interleaving explorer plus the
//!                seeded schedule fuzzer over the pure state machines
//!                (crash/soft-break injection, safety + liveness
//!                invariants, minimized repro on failure)
//!
//! `dilocox <cmd> --help` lists options; configs can also come from a TOML
//! file via `--config path.toml` (see configs/), including the
//! `[transport]` and `[faults]` sections.

use dilocox::config::{Algo, ExperimentConfig};
use dilocox::metrics::Table;
use dilocox::obs;
use dilocox::obs::report::{
    accounting_json, accounting_table, chrome_trace_events, round_accounting,
    validate_chrome_trace,
};
use dilocox::pipeline::exec::{json_num_or_null, stage_times_json};
use dilocox::protocol::sim as proto_sim;
use dilocox::report;
use dilocox::sim;
use dilocox::train::{run_experiment, RunOpts};
use dilocox::transport::elastic::{
    run_elastic, run_stage_worker, run_worker, ElasticConfig, ElasticOutcome,
    SpawnMode, StageWorkerOpts, WorkerOpts, Workload,
};
use dilocox::transport::faulty::FaultPlan;
use dilocox::transport::{ReduceTopology, TransportBackend};
use dilocox::util::cli::CliSpec;
use dilocox::util::json::{obj, Json};
use dilocox::util::{fmt_bytes, fmt_secs};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match argv.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&argv[1..]),
        Some("coordinate") => cmd_coordinate(&argv[1..]),
        Some("worker") => cmd_worker(&argv[1..]),
        Some("simulate") => cmd_simulate(&argv[1..]),
        Some("analyze") => cmd_analyze(&argv[1..]),
        Some("inspect") => cmd_inspect(&argv[1..]),
        Some("trace-check") => cmd_trace_check(&argv[1..]),
        Some("protocol-verify") => cmd_protocol_verify(&argv[1..]),
        Some("--help") | Some("-h") | None => {
            eprintln!("{}", toplevel_usage());
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n\n{}", toplevel_usage());
            2
        }
    };
    std::process::exit(code);
}

fn toplevel_usage() -> String {
    "dilocox — DiLoCoX decentralized-training reproduction\n\n\
     Usage: dilocox <subcommand> [options]\n\n\
     Subcommands:\n\
       train        real-numerics training run (PJRT artifacts)\n\
       coordinate   coordinator run (threaded local ring, or elastic\n\
                    multi-process TCP ring via --transport tcp)\n\
       worker       one elastic TCP ring worker (spawned by coordinate)\n\
       simulate     paper-scale DES throughput (Fig 4 / Table 1)\n\
       analyze      §2.4.1 communication-overhead analysis\n\
       inspect      summarize an artifact bundle\n\
       trace-check  validate a coordinate --trace export\n\
       protocol-verify  model-check the elastic membership protocol\n"
        .to_string()
}

fn build_cfg(args: &dilocox::util::cli::Args) -> Result<ExperimentConfig, String> {
    let mut cfg = if !args.get("config").is_empty() {
        ExperimentConfig::from_toml_file(args.get("config"))
            .map_err(|e| e.to_string())?
    } else {
        let algo = Algo::parse(args.get("algo")).map_err(|e| e.to_string())?;
        ExperimentConfig::default_for(args.get("preset"), algo)
    };
    if !args.get("outer-steps").is_empty() {
        cfg.train.outer_steps = args.get_usize("outer-steps")?;
    }
    if !args.get("local-steps").is_empty() {
        cfg.train.local_steps = args.get_usize("local-steps")?;
    }
    if !args.get("dp").is_empty() {
        cfg.parallel.dp = args.get_usize("dp")?;
        cfg.network.clusters = cfg.parallel.dp;
    }
    if !args.get("pp").is_empty() {
        cfg.parallel.pp = args.get_usize("pp")?;
    }
    if !args.get("micros").is_empty() {
        cfg.parallel.microbatches = args.get_usize("micros")?;
    }
    if args.flag("no-overlap") {
        cfg.train.overlap = false;
    }
    if args.flag("no-compression") {
        cfg.compression.enabled = false;
    }
    if !args.get("artifacts").is_empty() {
        cfg.artifacts_dir = args.get("artifacts").to_string();
    }
    cfg.validate().map_err(|e| e.to_string())?;
    Ok(cfg)
}

fn train_spec(name: &str, about: &str) -> CliSpec {
    CliSpec::new(name, about)
        .opt("config", "", "TOML config file (configs/*.toml)")
        .opt("preset", "small", "artifact preset: tiny | small | e2e100m")
        .opt("algo", "dilocox", "dilocox | allreduce | opendiloco | cocktail")
        .opt("outer-steps", "", "outer steps T")
        .opt("local-steps", "", "local steps H₁")
        .opt("dp", "", "data-parallel replicas D")
        .opt("pp", "", "pipeline stages M (coordinate: stage-parallel 1F1B, local threads or tcp processes)")
        .opt("micros", "", "in-flight microbatches U (with --pp > 1)")
        .opt("artifacts", "", "artifact dir override")
        .opt("csv", "", "write per-step metrics CSV here")
        .flag("no-overlap", "disable one-step-delay overlap (ablation)")
        .flag("no-compression", "disable gradient compression (ablation)")
        .flag("quiet", "suppress progress logs")
}

fn cmd_train(argv: &[String]) -> i32 {
    let spec = train_spec("dilocox train", "real-numerics training run");
    let args = match spec.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = match build_cfg(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let opts = RunOpts { quiet: args.flag("quiet"), ..Default::default() };
    match run_experiment(&cfg, &opts) {
        Ok(out) => {
            let m = &out.metrics;
            println!(
                "{}: final eval loss {:.4} | {} tokens | wire {} | modeled {} | {:.1} tok/s",
                cfg.algo.name(),
                m.final_eval_loss.unwrap_or(f32::NAN),
                m.total_tokens(),
                fmt_bytes(m.total_wire_bytes()),
                fmt_secs(m.total_elapsed()),
                m.tokens_per_sec()
            );
            if !args.get("csv").is_empty() {
                if let Err(e) = m.write_csv(args.get("csv")) {
                    eprintln!("writing csv: {e}");
                    return 1;
                }
                println!("wrote {}", args.get("csv"));
            }
            0
        }
        Err(e) => {
            eprintln!("train failed: {e:#}");
            1
        }
    }
}

fn cmd_coordinate(argv: &[String]) -> i32 {
    let spec = train_spec(
        "dilocox coordinate",
        "coordinator run (local threads or elastic TCP processes)",
    )
    .opt("transport", "", "local | tcp (default: config [transport])")
    .opt("dim", "64", "synthetic workload dimension (tcp fallback)")
    .opt("kill-round", "", "inject: kill --kill-rank at this round (tcp)")
    .opt("kill-rank", "1", "inject: rank to kill at --kill-round (tcp)")
    .opt("kill-stage", "0", "inject: stage process to kill (tcp, --pp > 1)")
    .opt("report", "", "write a run report JSON (incl. stage wall times) here")
    .opt("trace", "", "enable tracing and write the merged Chrome-trace JSON here (tcp)")
    .opt("reduce-topology", "", "flat | reordered | hier (default: config [transport])")
    .opt("schedule", "", "gpipe | 1f1b | interleaved | zero-bubble (default: config [parallel])")
    .opt("virtual-stages", "", "model chunks v per executor (default: config [parallel])")
    .opt("sites", "", "tcp: comma-separated per-rank site tags, e.g. 0,0,1,1 (hier)")
    .flag("synthetic", "tcp: force the synthetic workload (affine chain with --pp > 1)");
    let args = match spec.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut cfg = match build_cfg(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    if !args.get("transport").is_empty() {
        cfg.transport.backend = match TransportBackend::parse(args.get("transport")) {
            Ok(b) => b,
            Err(e) => {
                eprintln!("{e:#}");
                return 2;
            }
        };
    }
    if !args.get("reduce-topology").is_empty() {
        // Stored as the config string; validate() below rejects unknown
        // spellings with the same message as a bad TOML value.
        cfg.transport.reduce_topology = args.get("reduce-topology").to_string();
    }
    if !args.get("schedule").is_empty() {
        cfg.parallel.schedule = args.get("schedule").to_string();
    }
    if !args.get("virtual-stages").is_empty() {
        cfg.parallel.virtual_stages = match args.get_usize("virtual-stages") {
            Ok(v) => v.max(1),
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
    }
    if !args.get("kill-round").is_empty() {
        cfg.faults.enabled = true;
        cfg.faults.kill_round = match args.get_usize("kill-round") {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        cfg.faults.kill_rank = match args.get_usize("kill-rank") {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        cfg.faults.kill_stage = match args.get_usize("kill-stage") {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
    }
    // Re-validate: the transport/fault overrides above landed after
    // build_cfg's validation pass (e.g. --kill-rank out of range for dp).
    if let Err(e) = cfg.validate() {
        eprintln!("{e:#}");
        return 2;
    }
    if cfg.transport.backend == TransportBackend::Local && cfg.faults.enabled {
        eprintln!(
            "warning: [faults] / --kill-round apply only to --transport tcp; \
             the local threaded run ignores them"
        );
    }
    if !args.get("trace").is_empty() {
        cfg.trace.enabled = true;
        if cfg.transport.backend == TransportBackend::Local {
            eprintln!(
                "warning: --trace applies only to --transport tcp; the \
                 local threaded run ignores it"
            );
        }
    }
    match cfg.transport.backend {
        TransportBackend::Tcp => cmd_coordinate_tcp(&cfg, &args),
        TransportBackend::Local => cmd_coordinate_local(&cfg, &args),
    }
}

/// Write a run report JSON (pretty-printed) to `path`.
fn write_report(path: &str, json: &Json) -> Result<(), String> {
    std::fs::write(path, format!("{}\n", json.to_string_pretty()))
        .map_err(|e| format!("writing report {path}: {e}"))
}

/// Parse a `--sites 0,0,1,1` list of per-rank site tags.
fn parse_sites(s: &str) -> Result<Vec<u32>, String> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse::<u32>()
                .map_err(|_| format!("--sites: '{t}' is not a site tag (u32)"))
        })
        .collect()
}

fn elastic_report_json(
    cfg: &ExperimentConfig,
    ecfg: &ElasticConfig,
    out: &ElasticOutcome,
) -> Json {
    let rounds = Json::Arr(
        out.mean_loss_per_round()
            .into_iter()
            .map(|(r, mean, n)| {
                obj(vec![
                    ("round", Json::Num(r as f64)),
                    ("mean_loss", json_num_or_null(mean as f64)),
                    ("workers", Json::Num(n as f64)),
                ])
            })
            .collect(),
    );
    obj(vec![
        ("mode", Json::Str("elastic_tcp".to_string())),
        ("algo", Json::Str(cfg.algo.name().to_string())),
        ("dp", Json::Num(cfg.parallel.dp as f64)),
        ("pp", Json::Num(cfg.parallel.pp as f64)),
        ("epochs", Json::Num(out.epochs as f64)),
        (
            "survivors",
            Json::Arr(
                out.survivors
                    .iter()
                    .map(|s| Json::Num(*s as f64))
                    .collect(),
            ),
        ),
        // NaN (e.g. a skipped assembled eval) must not reach the writer —
        // a bare NaN literal is invalid JSON.
        ("final_eval", json_num_or_null(out.final_loss as f64)),
        ("total_wire_bytes", Json::Num(out.total_wire_bytes as f64)),
        ("rounds", rounds),
        // Measured per-stage step times from the fleet's heartbeats —
        // same shape as the threaded report, so the DES calibration
        // (`--calibrate-from`) consumes either.
        ("stage_times", stage_times_json(&out.stage_times)),
        ("reduce_topology", Json::Str(ecfg.reduce_topology.name().to_string())),
        (
            "sites",
            Json::Arr(ecfg.sites.iter().map(|s| Json::Num(*s as f64)).collect()),
        ),
        // Probed directed links (reordered topology only; empty otherwise) —
        // the DES consumes these the way `--calibrate-from` consumes
        // `stage_times`, closing the measure → model loop.
        (
            "links",
            Json::Arr(
                out.links
                    .iter()
                    .map(|(from, to, gbps, lat)| {
                        obj(vec![
                            ("from", Json::Num(*from as f64)),
                            ("to", Json::Num(*to as f64)),
                            ("gbps", json_num_or_null(*gbps)),
                            ("latency_ms", json_num_or_null(*lat)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn cmd_coordinate_local(
    cfg: &ExperimentConfig,
    args: &dilocox::util::cli::Args,
) -> i32 {
    let dir = cfg.artifacts_dir.clone();
    match dilocox::coordinator::run_threaded(cfg, &dir) {
        Ok(out) => {
            let rounds = cfg.train.outer_steps;
            for r in 1..=rounds {
                let losses: Vec<f32> = out
                    .reports
                    .iter()
                    .filter(|x| x.round == r)
                    .map(|x| x.mean_loss)
                    .collect();
                println!(
                    "round {r}: mean loss {:.4} over {} workers",
                    dilocox::util::mean(&losses),
                    losses.len()
                );
            }
            println!(
                "final eval {:.4}; ring traffic {}",
                out.final_eval,
                fmt_bytes(out.total_wire_bytes)
            );
            for t in &out.stage_times {
                println!(
                    "stage {}: mean {:.2} ms/step, max {:.2} ms ({} samples)",
                    t.stage,
                    1e3 * t.mean_step_secs,
                    1e3 * t.max_step_secs,
                    t.samples
                );
            }
            if !args.get("report").is_empty() {
                let rounds_json = Json::Arr(
                    (1..=rounds)
                        .map(|r| {
                            let ls: Vec<f32> = out
                                .reports
                                .iter()
                                .filter(|x| x.round == r && !x.mean_loss.is_nan())
                                .map(|x| x.mean_loss)
                                .collect();
                            obj(vec![
                                ("round", Json::Num(r as f64)),
                                (
                                    "mean_loss",
                                    json_num_or_null(
                                        dilocox::util::mean(&ls) as f64
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                );
                let j = obj(vec![
                    ("mode", Json::Str("threaded_local".to_string())),
                    ("algo", Json::Str(cfg.algo.name().to_string())),
                    ("dp", Json::Num(cfg.parallel.dp as f64)),
                    ("pp", Json::Num(cfg.parallel.pp as f64)),
                    ("final_eval", json_num_or_null(out.final_eval as f64)),
                    (
                        "total_wire_bytes",
                        Json::Num(out.total_wire_bytes as f64),
                    ),
                    ("rounds", rounds_json),
                    ("stage_times", stage_times_json(&out.stage_times)),
                ]);
                if let Err(e) = write_report(args.get("report"), &j) {
                    eprintln!("{e}");
                    return 1;
                }
                println!("wrote {}", args.get("report"));
            }
            0
        }
        Err(e) => {
            eprintln!("coordinate failed: {e:#}");
            1
        }
    }
}

/// Elastic multi-process path: spawn one `dilocox worker` per cluster —
/// or one per (cluster, stage) with `--pp > 1` — over loopback TCP;
/// survives injected/real process death by re-forming the (per-stage)
/// rings with the survivors.
fn cmd_coordinate_tcp(cfg: &ExperimentConfig, args: &dilocox::util::cli::Args) -> i32 {
    let have_artifacts = std::path::Path::new(&cfg.artifacts_dir).exists();
    let workload = if args.flag("synthetic") || !have_artifacts {
        if !have_artifacts && !args.flag("synthetic") {
            eprintln!(
                "artifacts {} missing — running the synthetic {} workload",
                cfg.artifacts_dir,
                if cfg.parallel.pp > 1 {
                    "multi-stage affine chain"
                } else {
                    "quadratic"
                }
            );
        }
        let dim = match args.get_usize("dim") {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        Workload::Quadratic { dim }
    } else {
        // Stage fleets must match the bundle's exported stage count —
        // fail at load time with an actionable message, not mid-run.
        if cfg.parallel.pp > 1 {
            match dilocox::runtime::Manifest::load(&cfg.artifacts_dir) {
                Ok(man) => {
                    if let Err(e) = cfg.validate_with_manifest(&man) {
                        eprintln!("{e:#}");
                        return 2;
                    }
                }
                Err(e) => {
                    eprintln!("loading {}: {e:#}", cfg.artifacts_dir);
                    return 1;
                }
            }
        }
        Workload::Runtime { artifacts_dir: cfg.artifacts_dir.clone() }
    };
    let mut ecfg = ElasticConfig::from_experiment(cfg, workload);
    if !args.get("sites").is_empty() {
        ecfg.sites = match parse_sites(args.get("sites")) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        if ecfg.sites.len() != ecfg.workers {
            eprintln!(
                "--sites lists {} tags but the fleet has {} workers",
                ecfg.sites.len(),
                ecfg.workers
            );
            return 2;
        }
    }
    if matches!(ecfg.workload, Workload::Quadratic { .. }) {
        if cfg.parallel.pp > 1 {
            // SyntheticPipeline-tuned defaults (same as the executor
            // tests): AdamW inner steps on the affine chain.
            ecfg.inner_lr = 0.05;
            ecfg.weight_decay = 0.0;
            ecfg.outer_lr = 0.7;
            ecfg.outer_momentum = 0.6;
        } else {
            // The transformer-tuned learning rates barely move the
            // synthetic quadratic; use the quadratic-tuned defaults (same
            // values as ElasticConfig::quadratic) so the demo shows
            // decisive convergence.
            ecfg.inner_lr = 0.25;
            ecfg.weight_decay = 0.0;
            ecfg.outer_lr = 0.5;
            ecfg.outer_momentum = 0.6;
        }
    }
    let exe = match std::env::current_exe() {
        Ok(p) => p.to_string_lossy().to_string(),
        Err(e) => {
            eprintln!("cannot locate own binary for worker spawn: {e}");
            return 1;
        }
    };
    match run_elastic(&ecfg, &SpawnMode::Process { exe }) {
        Ok(out) => {
            for (r, mean, n) in out.mean_loss_per_round() {
                println!("round {r}: mean loss {mean:.6} over {n} workers");
            }
            println!(
                "final eval {:.6}; survivors {:?} of {}; membership epochs {}; ring traffic {}",
                out.final_loss,
                out.survivors,
                out.started,
                out.epochs,
                fmt_bytes(out.total_wire_bytes)
            );
            if ecfg.pp_stages > 1 {
                println!(
                    "stage fleet: {} clusters x {} stage processes, per-stage rings",
                    out.started, ecfg.pp_stages
                );
            }
            if !args.get("report").is_empty() {
                let j = elastic_report_json(cfg, &ecfg, &out);
                if let Err(e) = write_report(args.get("report"), &j) {
                    eprintln!("{e}");
                    return 1;
                }
                println!("wrote {}", args.get("report"));
            }
            if !args.get("trace").is_empty() {
                let accounts = round_accounting(&out.trace_events);
                println!("{}", accounting_table(&accounts));
                // One file, two consumers: Perfetto/chrome://tracing load
                // the top-level `traceEvents` array and ignore the extra
                // keys; `--calibrate-from` reads `stage_times`; the
                // per-round accounting lives under `dilocox`.
                let doc = obj(vec![
                    ("traceEvents", chrome_trace_events(&out.trace_events)),
                    ("stage_times", stage_times_json(&out.stage_times)),
                    (
                        "dilocox",
                        obj(vec![("rounds", accounting_json(&accounts))]),
                    ),
                ]);
                if let Err(e) = write_report(args.get("trace"), &doc) {
                    eprintln!("{e}");
                    return 1;
                }
                println!(
                    "wrote {} ({} trace events)",
                    args.get("trace"),
                    out.trace_events.len()
                );
            }
            0
        }
        Err(e) => {
            eprintln!("elastic coordinate failed: {e:#}");
            1
        }
    }
}

/// Body of one elastic TCP worker process (spawned by `coordinate`).
fn cmd_worker(argv: &[String]) -> i32 {
    let spec = CliSpec::new(
        "dilocox worker",
        "elastic TCP ring worker (spawned by `dilocox coordinate --transport tcp`)",
    )
    .req("coord", "coordinator control address host:port")
    .opt("rank", "0", "worker rank (cluster id)")
    .opt("stage", "0", "pipeline stage of this process (with --stages > 1)")
    .opt("stages", "1", "pipeline stages M; > 1 joins the stage-parallel fleet")
    .opt("micros", "1", "in-flight microbatches U (with --stages > 1)")
    .opt("schedule", "1f1b", "gpipe | 1f1b | interleaved | zero-bubble")
    .opt("virtual-stages", "1", "model chunks v per executor (interleaved)")
    .opt("listen-base", "0", "deterministic listener base port (0 = ephemeral)")
    .opt("rounds", "8", "outer rounds T")
    .opt("local-steps", "8", "inner steps H per round")
    .opt("inner-lr", "0.25", "inner step size")
    .opt("weight-decay", "0.0", "inner AdamW weight decay (runtime workload)")
    .opt("outer-lr", "0.5", "outer Nesterov step size")
    .opt("outer-momentum", "0.6", "outer Nesterov momentum")
    .opt("seed", "1234", "experiment seed")
    .opt("workload", "quad", "quad | runtime")
    .opt("dim", "64", "quadratic workload dimension")
    .opt("artifacts", "", "artifact dir (runtime workload)")
    .opt("site", "0", "site tag for the hierarchical two-level reduce")
    .opt("reduce-topology", "flat", "flat | reordered | hier")
    .opt("ring-timeout-ms", "5000", "ring socket timeout")
    .opt("connect-timeout-ms", "5000", "ring formation deadline")
    .opt("comm-pool", "1", "persistent comm-thread pool size (1 = off)")
    .opt("pipeline-depth", "1", "reduce pipeline depth (1 = sequential)")
    .flag("overlap", "one-step-delay overlap of comm and local training (§2.3)")
    .flag("trace", "record trace spans and ship them to the coordinator")
    .opt("trace-dir", "", "also tee trace batches to <dir>/<role>.jsonl")
    .opt("fault-seed", "7", "fault injection seed")
    .opt("fault-delay-prob", "0", "probability a sent message is delayed")
    .opt("fault-delay-ms", "0", "max injected delay per message, ms")
    .opt("fault-kill-round", "0", "exit at this round (0 = never)")
    .opt("fault-break-round", "0", "soft ring break at this round (0 = never)")
    .opt("fault-straggler-ms", "0", "fixed extra latency per send, ms");
    let args = match spec.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let opts = match worker_opts_from_args(&args) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let stages = match args.get_usize("stages") {
        Ok(v) => v,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // Role tag (`c3` / `c3.s1`) prefixes every log line this process
    // emits — the interleaved stderr of a fleet stays attributable.
    let role = if stages > 1 {
        format!("c{}.s{}", opts.rank, args.get_usize("stage").unwrap_or(0))
    } else {
        format!("c{}", opts.rank)
    };
    dilocox::util::log::set_role(&role);
    if args.flag("trace") {
        obs::set_enabled(true);
        let dir = args.get("trace-dir");
        if !dir.is_empty() {
            obs::set_journal(Some(
                std::path::Path::new(dir).join(format!("{role}.jsonl")),
            ));
        }
    }
    if stages > 1 {
        let sopts = match stage_worker_opts_from_args(&args, opts, stages) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        };
        return match run_stage_worker(&sopts) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!(
                    "stage worker {}.{} failed: {e:#}",
                    sopts.base.rank, sopts.stage
                );
                1
            }
        };
    }
    match run_worker(&opts) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("worker {} failed: {e:#}", opts.rank);
            1
        }
    }
}

fn stage_worker_opts_from_args(
    args: &dilocox::util::cli::Args,
    base: WorkerOpts,
    stages: usize,
) -> Result<StageWorkerOpts, String> {
    let listen_base = args.get_usize("listen-base")?;
    if listen_base > u16::MAX as usize {
        return Err(format!("--listen-base {listen_base} exceeds 65535"));
    }
    Ok(StageWorkerOpts {
        base,
        stage: args.get_usize("stage")? as u32,
        stages: stages as u32,
        micros: args.get_usize("micros")?.max(1),
        schedule: args.get("schedule").to_string(),
        virtual_stages: args.get_usize("virtual-stages")?.max(1),
        listen_base: listen_base as u16,
    })
}

fn worker_opts_from_args(args: &dilocox::util::cli::Args) -> Result<WorkerOpts, String> {
    let workload = match args.get("workload") {
        "quad" | "quadratic" => Workload::Quadratic { dim: args.get_usize("dim")? },
        "runtime" => {
            let dir = args.get("artifacts");
            if dir.is_empty() {
                return Err("--workload runtime needs --artifacts".to_string());
            }
            Workload::Runtime { artifacts_dir: dir.to_string() }
        }
        other => return Err(format!("unknown workload '{other}' (quad | runtime)")),
    };
    let plan = FaultPlan {
        seed: args.get_u64("fault-seed")?,
        delay_prob: args.get_f64("fault-delay-prob")?,
        max_delay_ms: args.get_u64("fault-delay-ms")?,
        kill_round: args.get_usize("fault-kill-round")?,
        break_round: args.get_usize("fault-break-round")?,
        straggler_ms: args.get_u64("fault-straggler-ms")?,
        exit_on_kill: true,
    };
    Ok(WorkerOpts {
        coord: args.get("coord").to_string(),
        rank: args.get_usize("rank")? as u32,
        rounds: args.get_usize("rounds")?,
        local_steps: args.get_usize("local-steps")?,
        inner_lr: args.get_f64("inner-lr")? as f32,
        weight_decay: args.get_f64("weight-decay")? as f32,
        outer_lr: args.get_f64("outer-lr")? as f32,
        outer_momentum: args.get_f64("outer-momentum")? as f32,
        seed: args.get_u64("seed")?,
        workload,
        overlap: args.flag("overlap"),
        ring_timeout_ms: args.get_u64("ring-timeout-ms")?,
        connect_timeout_ms: args.get_u64("connect-timeout-ms")?,
        comm_pool_size: args.get_usize("comm-pool")?.max(1),
        pipeline_depth: args.get_usize("pipeline-depth")?.max(1),
        site: args.get_usize("site")? as u32,
        reduce_topology: ReduceTopology::parse(args.get("reduce-topology"))
            .map_err(|e| format!("{e:#}"))?,
        faults: if plan.is_quiet() { None } else { Some(plan) },
    })
}

fn cmd_simulate(argv: &[String]) -> i32 {
    let spec = CliSpec::new("dilocox simulate", "paper-scale DES throughput")
        .opt("scale", "both", "1.3b | 107b | both")
        .opt("rounds", "12", "outer rounds to simulate");
    let args = match spec.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let rounds = args.get_usize("rounds").unwrap_or(12);
    let scales: Vec<sim::ScaleConfig> = match args.get("scale") {
        "1.3b" => vec![sim::ScaleConfig::opt_1_3b()],
        "107b" => vec![sim::ScaleConfig::qwen_107b()],
        _ => vec![sim::ScaleConfig::opt_1_3b(), sim::ScaleConfig::qwen_107b()],
    };
    for s in scales {
        let rows = sim::figure4_row(&s, rounds);
        let paper: &[(&str, f64)] = if s.params > 10e9 {
            &report::paper::FIG4_107B
        } else {
            &report::paper::FIG4_1_3B
        };
        println!("{}", report::figure4_table(&s.name, paper, &rows));
    }
    0
}

fn cmd_analyze(argv: &[String]) -> i32 {
    let spec = CliSpec::new("dilocox analyze", "§2.4.1 comm-overhead analysis")
        .opt("params", "100e9", "model parameters θ")
        .opt("clusters", "3", "clusters C")
        .opt("gbps", "1.0", "inter-cluster bandwidth")
        .opt("local-steps", "500", "H (1 s each, paper's example)");
    let args = match spec.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let theta: f64 = args.get("params").parse().unwrap_or(100e9);
    let c = args.get_usize("clusters").unwrap_or(3);
    let gbps = args.get_f64("gbps").unwrap_or(1.0);
    let h = args.get_usize("local-steps").unwrap_or(500);
    let wire = 2.0 * (c as f64 - 1.0) / c as f64 * theta * 4.0;
    let net = dilocox::config::NetworkConfig {
        clusters: c,
        inter_bw_gbps: gbps,
        intra_bw_gbps: 100.0,
        latency_ms: 0.0,
    };
    let secs = dilocox::comm::ring_allreduce_seconds((theta * 4.0) as u64, &net);
    let local = h as f64 * 1.0;
    let mut t = Table::new(&["quantity", "value", "paper (§2.4.1)"]);
    t.row(&[
        "ring wire between clusters".into(),
        format!("{:.1} GB", wire / 1e9),
        format!("{:.1} GB", report::paper::COMM_ANALYSIS_GB),
    ]);
    t.row(&[
        "transfer time".into(),
        format!("{:.2} h", secs / 3600.0),
        format!("{:.2} h", report::paper::COMM_ANALYSIS_HOURS),
    ]);
    t.row(&[
        format!("local training (H={h} x 1 s)"),
        format!("{:.2} h", local / 3600.0),
        "0.13 h".into(),
    ]);
    t.row(&[
        "idle fraction without overlap/compression".into(),
        format!("{:.0}%", 100.0 * (secs - local).max(0.0) / secs),
        "~88%".into(),
    ]);
    println!("{}", t.render());
    0
}

/// Validate a `coordinate --trace` export: required fields per event,
/// spans well-nested within each thread track, `round` markers monotone,
/// and (with --expect-recovery) at least one recovery span — what CI
/// runs against the churn fleet's trace.
fn cmd_trace_check(argv: &[String]) -> i32 {
    let spec = CliSpec::new(
        "dilocox trace-check",
        "validate a coordinate --trace export",
    )
    .req("input", "trace JSON written by coordinate --trace")
    .flag("expect-recovery", "require recovery.* spans (churn runs)");
    let args = match spec.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let path = args.get("input");
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("reading {path}: {e}");
            return 1;
        }
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("parsing {path}: {e}");
            return 1;
        }
    };
    match validate_chrome_trace(&doc, args.flag("expect-recovery")) {
        Ok(n) => {
            println!("{path}: ok — {n} events, well-nested, rounds monotone");
            0
        }
        Err(e) => {
            eprintln!("{path}: INVALID — {e:#}");
            1
        }
    }
}

fn cmd_protocol_verify(argv: &[String]) -> i32 {
    let spec = CliSpec::new(
        "dilocox protocol-verify",
        "model-check the elastic membership protocol (explorer + fuzzer)",
    )
    .opt("workers", "3", "fleet size")
    .opt("rounds", "2", "scheduled outer rounds")
    .opt("crashes", "1", "crash injections allowed per execution")
    .opt("breaks", "1", "soft-break injections allowed per execution")
    .opt("preemptions", "2", "explorer schedule-deviation budget")
    .opt("max-execs", "200000", "explorer execution cap")
    .opt("min-execs", "1000", "fail if the explorer covers fewer executions")
    .opt("fuzz-seeds", "500", "random schedules to fuzz")
    .opt("fuzz-base-seed", "1234", "base seed for the fuzz schedules")
    .opt("repro-out", "", "write the minimized repro here on failure")
    .flag("sync", "disable one-step-delay overlap (no in-flight deltas)");
    let args = match spec.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match run_protocol_verify(&args) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

fn run_protocol_verify(args: &dilocox::util::cli::Args) -> Result<i32, String> {
    let cfg = proto_sim::SimConfig {
        workers: args.get_usize("workers")? as u32,
        rounds: args.get_usize("rounds")? as u32,
        overlap: !args.flag("sync"),
        crashes: args.get_usize("crashes")? as u32,
        breaks: args.get_usize("breaks")? as u32,
    };
    let preemptions = args.get_usize("preemptions")? as u32;
    let max_execs = args.get_u64("max-execs")?;
    let min_execs = args.get_u64("min-execs")?;
    let fuzz_seeds = args.get_usize("fuzz-seeds")? as u32;
    let base_seed = args.get_u64("fuzz-base-seed")?;
    let repro_out = args.get("repro-out");

    println!(
        "protocol-verify: {} workers, {} rounds, overlap={}, \
         crashes={}, breaks={}",
        cfg.workers, cfg.rounds, cfg.overlap, cfg.crashes, cfg.breaks
    );
    match proto_sim::explore(cfg, preemptions, max_execs) {
        Ok(stats) => {
            println!(
                "explore: {} executions, max {} steps, {} preemptions{}",
                stats.executions,
                stats.max_steps,
                preemptions,
                if stats.capped { " (capped)" } else { "" }
            );
            if stats.executions < min_execs {
                eprintln!(
                    "explore: only {} executions covered (< {min_execs}); \
                     raise --preemptions or the fault budgets",
                    stats.executions
                );
                return Ok(1);
            }
        }
        Err(v) => {
            report_violation("explore", &cfg, &v, repro_out);
            return Ok(1);
        }
    }
    match proto_sim::fuzz(cfg, fuzz_seeds, base_seed) {
        Ok(n) => {
            println!("fuzz: {n} seeded schedules clean (base seed {base_seed})")
        }
        Err(v) => {
            report_violation("fuzz", &cfg, &v, repro_out);
            return Ok(1);
        }
    }
    println!("protocol-verify: ok");
    Ok(0)
}

/// Print a protocol violation and (when requested) persist the minimized
/// repro — the `SimConfig` plus the deviation list that
/// `protocol::sim::replay` re-executes deterministically.
fn report_violation(
    phase: &str,
    cfg: &proto_sim::SimConfig,
    v: &proto_sim::Violation,
    out: &str,
) {
    eprintln!("{phase}: {v}");
    if out.is_empty() {
        return;
    }
    let body = format!("phase: {phase}\nconfig: {cfg:?}\n{v}\n");
    match std::fs::write(out, body) {
        Ok(()) => eprintln!("minimized repro written to {out}"),
        Err(e) => eprintln!("writing repro to {out}: {e}"),
    }
}

fn cmd_inspect(argv: &[String]) -> i32 {
    let spec = CliSpec::new("dilocox inspect", "summarize an artifact bundle")
        .opt("artifacts", "artifacts/tiny", "bundle directory");
    let args = match spec.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match dilocox::runtime::Manifest::load(args.get("artifacts")) {
        Ok(m) => {
            println!(
                "preset {} | {} params | pallas={} | {} programs",
                m.preset,
                m.param_count,
                m.use_pallas,
                m.programs.len()
            );
            let mut t = Table::new(&["program", "inputs", "outputs", "file"]);
            for (name, p) in &m.programs {
                let sig = |ts: &[dilocox::runtime::TensorSig]| {
                    ts.iter()
                        .map(|t| format!("{:?}", t.shape))
                        .collect::<Vec<_>>()
                        .join(" ")
                };
                t.row(&[
                    name.clone(),
                    sig(&p.inputs),
                    sig(&p.outputs),
                    p.file.clone(),
                ]);
            }
            println!("{}", t.render());
            0
        }
        Err(e) => {
            eprintln!("inspect failed: {e:#}");
            1
        }
    }
}
