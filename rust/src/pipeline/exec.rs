//! Stage-parallel 1F1B executor: pipeline parallelism run for real.
//!
//! Each DP cluster runs its model as `stages` stage executors — one OS
//! thread per stage — each executing its own 1F1B op stream
//! ([`super::one_f_one_b_schedule`]) in order.  Activations flow down and
//! grad-activations flow up over blocking mpsc channels, which realize
//! exactly the dependency rules that [`super::execute_streams`] encodes
//! for the validator and the DES: a stage's next op blocks until its
//! upstream forward (or downstream backward) has delivered.
//!
//! The paper's §2.2 Dual Optimizer Policy is realized literally: every
//! stage thread holds ONLY its own parameter shard plus its slice of
//! *both* optimizers (inner AdamW moments + outer Nesterov buffer — a
//! per-stage [`DualOptimizer`]), so optimizer VRAM scales down with the
//! stage count.  Outer rounds run through the shared
//! [`crate::rounds::RoundEngine`]: per-stage pseudo-gradients reduce over
//! a per-stage [`RingTransport`] ring that connects the same stage across
//! DP clusters, so PP composes with any transport backend (local mpsc,
//! TCP, fault-injecting wrappers) and with one-step-delay overlap — each
//! stage's collective runs on its own comm thread while the stage trains
//! the next H local steps.
//!
//! Workloads implement [`PipelineWorkload`]/[`StageCompute`]: the PJRT
//! artifact-backed implementation lives in [`crate::coordinator`]; the
//! [`SyntheticPipeline`] here (a depth-M affine chain with per-worker
//! targets) exercises the full executor — schedule, channels, per-stage
//! duals, ring reduction, overlap — with no artifacts at all.
//!
//! Data-bearing stages (first and last) must draw identical input
//! streams: they are constructed with the same seed and advance in
//! lockstep (one draw per inner step), so the tokens consumed at stage 0
//! and the labels consumed at the last stage always belong to the same
//! microbatch.

use crate::comm::ring::build_ring;
use crate::compress::Method;
use crate::optim::DualOptimizer;
use crate::pipeline::{one_f_one_b_schedule, validate_schedule, Cell};
use crate::rounds::{movement, RoundEngine, RingLane};
use crate::runtime::manifest::ParamEntry;
use crate::transport::RingTransport;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc;

/// One pipeline stage's compute, owned by its executor thread (built
/// *inside* the thread via [`PipelineWorkload::make_stage`], so
/// implementations may hold thread-bound state like a PJRT runtime).
pub trait StageCompute {
    /// Flat parameter count of this stage.
    fn numel(&self) -> usize;
    /// Initial stage parameters.
    fn init(&self) -> Result<Vec<f32>>;
    /// Parameter layout for wire compression (a single 1-D entry is a
    /// valid fallback when the layout is opaque).
    fn param_spec(&self) -> Vec<ParamEntry>;
    /// Advance to the next inner step's data (called once per inner
    /// step, before the microbatch schedule runs).
    fn next_step(&mut self) -> Result<()>;
    /// Forward one microbatch.  `acts_in` is `None` on stage 0.  Returns
    /// the activations to ship downstream (`None` on the last stage).
    /// Implementations stash whatever their backward needs.
    fn forward(
        &mut self,
        params: &[f32],
        micro: usize,
        acts_in: Option<Vec<f32>>,
    ) -> Result<Option<Vec<f32>>>;
    /// Backward one microbatch.  `grad_in` is `None` on the last stage.
    /// Returns (parameter gradients, grad-activations to ship upstream
    /// (`None` on stage 0), microbatch loss (`Some` on the last stage)).
    fn backward(
        &mut self,
        params: &[f32],
        micro: usize,
        grad_in: Option<Vec<f32>>,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>, Option<f32>)>;
}

/// A model partitioned into pipeline stages: builds per-(worker, stage)
/// compute and evaluates assembled full parameter vectors.  `Sync`
/// because one instance is shared by reference across all stage threads.
pub trait PipelineWorkload: Sync {
    fn stages(&self) -> usize;
    /// In-flight microbatches per inner step.
    fn micros(&self) -> usize;
    fn stage_numel(&self, stage: usize) -> usize;
    fn make_stage(&self, worker: usize, stage: usize) -> Result<Box<dyn StageCompute>>;
    /// Eval loss of an assembled (stage-concatenated) parameter vector.
    fn eval(&self, full_params: &[f32]) -> Result<f32>;
}

#[derive(Clone, Debug)]
pub struct PipelineRunOpts {
    pub rounds: usize,
    /// H — inner steps per outer round.
    pub local_steps: usize,
    pub inner_lr: f32,
    pub weight_decay: f32,
    pub outer_lr: f32,
    pub outer_momentum: f32,
    /// One-step-delay overlap of the per-stage collectives (§2.3).
    pub overlap: bool,
    pub error_feedback: bool,
    /// AllReduce-compatible wire compression for the per-stage rings.
    pub method: Method,
    pub seed: u64,
}

impl Default for PipelineRunOpts {
    fn default() -> Self {
        PipelineRunOpts {
            rounds: 4,
            local_steps: 8,
            inner_lr: 0.05,
            weight_decay: 0.0,
            outer_lr: 0.7,
            outer_momentum: 0.9,
            overlap: false,
            error_feedback: false,
            method: Method::None,
            seed: 1234,
        }
    }
}

/// Per-(worker, stage, round) telemetry.
#[derive(Clone, Debug)]
pub struct StageRoundReport {
    pub worker: usize,
    pub stage: usize,
    pub round: usize,
    /// Mean microbatch loss over the round (last stage only; NaN on
    /// stages that never see the labels).
    pub mean_loss: f32,
    /// Payload bytes of the reduction completed during this round (zero
    /// on the first overlap round — nothing was in flight yet).
    pub wire_bytes: u64,
}

#[derive(Debug)]
pub struct PipelineOutcome {
    pub reports: Vec<StageRoundReport>,
    pub final_eval: f32,
    /// Worker 0's assembled params (stage concatenation == the single
    /// flat layout; all workers are verified to agree).
    pub final_params: Vec<f32>,
    pub total_wire_bytes: u64,
}

impl PipelineOutcome {
    /// Mean last-stage loss per round across workers.
    pub fn mean_loss_per_round(&self) -> Vec<(usize, f32)> {
        let rounds = self.reports.iter().map(|r| r.round).max().unwrap_or(0);
        let mut out = Vec::new();
        for r in 1..=rounds {
            let ls: Vec<f32> = self
                .reports
                .iter()
                .filter(|x| x.round == r && !x.mean_loss.is_nan())
                .map(|x| x.mean_loss)
                .collect();
            if !ls.is_empty() {
                out.push((r, ls.iter().sum::<f32>() / ls.len() as f32));
            }
        }
        out
    }
}

/// Per-stage channel plumbing inside one worker.
#[derive(Default)]
struct Plumbing {
    acts_rx: Option<mpsc::Receiver<(usize, Vec<f32>)>>,
    acts_tx: Option<mpsc::Sender<(usize, Vec<f32>)>>,
    grads_rx: Option<mpsc::Receiver<(usize, Vec<f32>)>>,
    grads_tx: Option<mpsc::Sender<(usize, Vec<f32>)>>,
}

/// Build the per-stage DP rings over the local mpsc backend:
/// `rings[worker][stage]` — stage s of every worker shares one ring.
pub fn local_stage_rings(dp: usize, stages: usize) -> Vec<Vec<Box<dyn RingTransport>>> {
    let mut rings: Vec<Vec<Box<dyn RingTransport>>> =
        (0..dp).map(|_| Vec::with_capacity(stages)).collect();
    for _s in 0..stages {
        for (w, m) in build_ring(dp).into_iter().enumerate() {
            rings[w].push(Box::new(m));
        }
    }
    rings
}

/// Run `opts.rounds` outer rounds of stage-parallel 1F1B training:
/// `dp × stages` executor threads, per-stage dual optimizers, per-stage
/// ring reduction of pseudo-gradients through the shared round engine.
pub fn run_pipeline(
    workload: &dyn PipelineWorkload,
    dp: usize,
    rings: Vec<Vec<Box<dyn RingTransport>>>,
    opts: &PipelineRunOpts,
) -> Result<PipelineOutcome> {
    let m = workload.stages();
    let micros = workload.micros();
    if dp == 0 || m == 0 {
        return Err(anyhow!("need at least one worker and one stage"));
    }
    if micros == 0 {
        return Err(anyhow!("need at least one microbatch"));
    }
    if rings.len() != dp || rings.iter().any(|r| r.len() != m) {
        return Err(anyhow!(
            "ring shape mismatch: want {dp} workers x {m} stages"
        ));
    }
    if !opts.method.allreduce_compatible() {
        return Err(anyhow!(
            "stage-parallel path needs AllReduce-compatible compression"
        ));
    }
    let streams = one_f_one_b_schedule(m, micros);
    validate_schedule(&streams, micros)
        .map_err(|e| anyhow!("invalid 1F1B schedule: {e}"))?;

    let (tx_report, rx_report) = mpsc::channel::<StageRoundReport>();
    let results: Vec<Result<(Vec<f32>, u64)>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(dp * m);
        for (w, worker_rings) in rings.into_iter().enumerate() {
            // Intra-worker channels: acts flow s -> s+1, grads s+1 -> s.
            let mut plumb: Vec<Plumbing> =
                (0..m).map(|_| Plumbing::default()).collect();
            for b in 0..m.saturating_sub(1) {
                let (ta, ra) = mpsc::channel();
                plumb[b].acts_tx = Some(ta);
                plumb[b + 1].acts_rx = Some(ra);
                let (tg, rg) = mpsc::channel();
                plumb[b + 1].grads_tx = Some(tg);
                plumb[b].grads_rx = Some(rg);
            }
            for (s, (pl, ring)) in
                plumb.into_iter().zip(worker_rings).enumerate()
            {
                let stream = streams[s].clone();
                let tx = tx_report.clone();
                handles.push(scope.spawn(move || {
                    stage_main(workload, w, s, pl, ring, opts, stream, tx)
                        .with_context(|| format!("worker {w} stage {s}"))
                }));
            }
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    drop(tx_report);

    let mut reports: Vec<StageRoundReport> = rx_report.into_iter().collect();
    reports.sort_by_key(|r| (r.round, r.worker, r.stage));

    // Assemble per-worker full vectors (stage order == single layout).
    let mut stage_params: Vec<Vec<f32>> = Vec::with_capacity(dp * m);
    let mut total_wire = 0u64;
    for r in results {
        let (p, wire) = r?;
        total_wire += wire;
        stage_params.push(p);
    }
    let mut assembled: Vec<Vec<f32>> = Vec::with_capacity(dp);
    for w in 0..dp {
        let mut full = Vec::new();
        for s in 0..m {
            full.extend_from_slice(&stage_params[w * m + s]);
        }
        assembled.push(full);
    }
    // Every worker must agree (per-stage ring algebra is symmetric);
    // verify instead of trusting.
    let p0 = &assembled[0];
    for pi in &assembled[1..] {
        let max_dev = p0
            .iter()
            .zip(pi)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        if max_dev > 1e-4 {
            return Err(anyhow!("workers diverged: max param dev {max_dev}"));
        }
    }
    let final_eval = workload.eval(p0)?;
    Ok(PipelineOutcome {
        reports,
        final_eval,
        final_params: assembled.swap_remove(0),
        total_wire_bytes: total_wire,
    })
}

/// One stage executor thread: run the 1F1B stream for H inner steps per
/// round, step the per-stage dual optimizer, and close each round through
/// the shared outer-round engine over this stage's DP ring.
#[allow(clippy::too_many_arguments)]
fn stage_main(
    workload: &dyn PipelineWorkload,
    worker: usize,
    stage: usize,
    plumb: Plumbing,
    ring: Box<dyn RingTransport>,
    opts: &PipelineRunOpts,
    stream: Vec<Cell>,
    tx_report: mpsc::Sender<StageRoundReport>,
) -> Result<(Vec<f32>, u64)> {
    let mut compute = workload.make_stage(worker, stage)?;
    let n = compute.numel();
    let mut params = compute.init()?;
    if params.len() != n {
        return Err(anyhow!("init len {} != numel {n}", params.len()));
    }
    let micros = workload.micros();

    // §2.2: this thread holds only this stage's optimizer pair.
    let DualOptimizer { mut inner, outer } = DualOptimizer::new(
        n,
        opts.inner_lr,
        opts.weight_decay,
        opts.outer_lr,
        opts.outer_momentum,
    );
    let mut engine = RoundEngine::new(
        params.clone(),
        1,
        outer,
        opts.overlap,
        opts.error_feedback,
    );
    // Per-stage compressor seed: identical on every worker (the ring
    // peers must derive the same low-rank bases), decorrelated across
    // stages; stage 0 reduces exactly like the single-stage path.
    let stage_seed =
        opts.seed ^ (stage as u64).wrapping_mul(0x9e3779b97f4a7c15);
    let mut lane = RingLane::new(
        ring,
        opts.method.clone(),
        stage_seed,
        compute.param_spec(),
        opts.overlap,
    );

    for round in 1..=opts.rounds {
        lane.begin_round(round)?; // fault-injection hook
        let anchor = params.clone();
        let mut loss_acc = 0.0f64;
        let mut loss_n = 0usize;
        for _step in 0..opts.local_steps {
            compute.next_step()?;
            let mut grad_acc = vec![0.0f32; n];
            for cell in &stream {
                if cell.is_forward {
                    let acts_in = match &plumb.acts_rx {
                        Some(rx) => {
                            let (mi, a) = rx.recv().map_err(|_| {
                                anyhow!("upstream stage hung up")
                            })?;
                            if mi != cell.micro {
                                return Err(anyhow!(
                                    "acts for micro {mi}, expected {}",
                                    cell.micro
                                ));
                            }
                            Some(a)
                        }
                        None => None,
                    };
                    let out = compute.forward(&params, cell.micro, acts_in)?;
                    if let Some(tx) = &plumb.acts_tx {
                        let a = out.ok_or_else(|| {
                            anyhow!("stage {stage} produced no activations")
                        })?;
                        tx.send((cell.micro, a)).map_err(|_| {
                            anyhow!("downstream stage hung up")
                        })?;
                    }
                } else {
                    let grad_in = match &plumb.grads_rx {
                        Some(rx) => {
                            let (mi, g) = rx.recv().map_err(|_| {
                                anyhow!("downstream stage hung up")
                            })?;
                            if mi != cell.micro {
                                return Err(anyhow!(
                                    "grads for micro {mi}, expected {}",
                                    cell.micro
                                ));
                            }
                            Some(g)
                        }
                        None => None,
                    };
                    let (gp, gout, loss) =
                        compute.backward(&params, cell.micro, grad_in)?;
                    if gp.len() != n {
                        return Err(anyhow!(
                            "stage grad len {} != numel {n}",
                            gp.len()
                        ));
                    }
                    for (a, b) in grad_acc.iter_mut().zip(&gp) {
                        *a += b;
                    }
                    if let Some(tx) = &plumb.grads_tx {
                        let g = gout.ok_or_else(|| {
                            anyhow!("stage {stage} produced no upstream grads")
                        })?;
                        tx.send((cell.micro, g)).map_err(|_| {
                            anyhow!("upstream stage hung up")
                        })?;
                    }
                    if let Some(l) = loss {
                        loss_acc += l as f64;
                        loss_n += 1;
                    }
                }
            }
            // Mean gradient over microbatches, one inner AdamW step.
            let inv = 1.0 / micros as f32;
            grad_acc.iter_mut().for_each(|g| *g *= inv);
            inner.step(&mut params, &grad_acc);
        }

        // Per-stage outer round through the shared engine.
        let mv = movement(&anchor, &params);
        if engine.finish_round(vec![mv], round as u64, &mut lane)?.is_some()
        {
            params.copy_from_slice(engine.theta());
        }
        tx_report
            .send(StageRoundReport {
                worker,
                stage,
                round,
                mean_loss: if loss_n > 0 {
                    (loss_acc / loss_n as f64) as f32
                } else {
                    f32::NAN
                },
                wire_bytes: lane.wire_last,
            })
            .ok();
    }
    // Trailing in-flight reduction (overlap flush at shutdown).
    if engine.drain(&mut lane)?.is_some() {
        params.copy_from_slice(engine.theta());
    }
    Ok((params, lane.wire_total))
}

// ---------------------------------------------------------------------------
// Synthetic multi-stage workload (no artifacts)
// ---------------------------------------------------------------------------

/// Artifact-free depth-M affine chain with per-worker targets:
///
/// ```text
/// a_0 = g_0·x + w_0,   a_s = g_s·a_{s-1} + w_s   (elementwise, dim k)
/// loss = ½·mean((a_{M-1} − y)²),   y = (Π g_s)·x + c_w
/// ```
///
/// where `g_s` are fixed per-stage gains and `c_w = c_shared + 0.1·n_w`
/// is each worker's displaced target (the heterogeneous-shard setup of
/// the elastic quadratic workload, stretched over a real pipeline).  The
/// optimum is realizable, gradients are stage-dependent (each stage's
/// grad carries its downstream gain product, so mis-routed grads are
/// caught), and eval has a closed form: the input term cancels, leaving
/// `½·mean((Σ_s (Π_{j>s} g_j)·w_s − c_shared)²)`.
#[derive(Clone, Debug)]
pub struct SyntheticPipeline {
    pub stages: usize,
    pub micros: usize,
    /// Activation / per-stage parameter dimension k.
    pub dim: usize,
    pub seed: u64,
}

impl SyntheticPipeline {
    pub fn new(stages: usize, micros: usize, dim: usize, seed: u64) -> Self {
        assert!(stages >= 1 && micros >= 1 && dim >= 1);
        SyntheticPipeline { stages, micros, dim, seed }
    }

    /// Per-stage gain g_s in [0.85, 1.15] — stage-dependent so gradient
    /// routing errors change the numbers.
    fn gain(&self, s: usize) -> f32 {
        0.85 + 0.3 * (s as f32 + 1.0) / self.stages as f32
    }

    /// Π_{j>s} g_j — the factor a stage's parameter carries to the output.
    fn downstream_gain(&self, s: usize) -> f32 {
        (s + 1..self.stages).map(|j| self.gain(j)).product()
    }

    /// Π over all stages (the input's path to the output).
    fn total_gain(&self) -> f32 {
        (0..self.stages).map(|s| self.gain(s)).product()
    }

    fn shared_target(&self) -> Vec<f32> {
        let mut c = vec![0.0f32; self.dim];
        Pcg32::new(self.seed ^ 0x7a67, 0).fill_normal(&mut c, 0.0, 1.0);
        c
    }

    fn worker_target(&self, worker: usize) -> Vec<f32> {
        let shared = self.shared_target();
        let mut noise = vec![0.0f32; self.dim];
        Pcg32::new(self.seed ^ 0x7a67, 1 + worker as u64)
            .fill_normal(&mut noise, 0.0, 1.0);
        shared
            .iter()
            .zip(&noise)
            .map(|(s, n)| s + 0.1 * n)
            .collect()
    }
}

impl PipelineWorkload for SyntheticPipeline {
    fn stages(&self) -> usize {
        self.stages
    }

    fn micros(&self) -> usize {
        self.micros
    }

    fn stage_numel(&self, _stage: usize) -> usize {
        self.dim
    }

    fn make_stage(&self, worker: usize, stage: usize) -> Result<Box<dyn StageCompute>> {
        if stage >= self.stages {
            return Err(anyhow!("stage {stage} out of range"));
        }
        Ok(Box::new(SyntheticStage {
            cfg: self.clone(),
            stage,
            // First and last stage draw the IDENTICAL input stream.
            data_rng: Pcg32::new(self.seed ^ 0xda7a, worker as u64),
            xs: Vec::new(),
            target: self.worker_target(worker),
            stash: HashMap::new(),
        }))
    }

    fn eval(&self, full_params: &[f32]) -> Result<f32> {
        if full_params.len() != self.stages * self.dim {
            return Err(anyhow!(
                "assembled params len {} != {}",
                full_params.len(),
                self.stages * self.dim
            ));
        }
        // Effective output bias Σ_s (Π_{j>s} g_j)·w_s vs the shared
        // target; the input term cancels exactly (see type docs).
        let shared = self.shared_target();
        let mut acc = 0.0f64;
        for i in 0..self.dim {
            let mut eff = 0.0f32;
            for s in 0..self.stages {
                eff += self.downstream_gain(s)
                    * full_params[s * self.dim + i];
            }
            let d = (eff - shared[i]) as f64;
            acc += d * d;
        }
        Ok((0.5 * acc / self.dim as f64) as f32)
    }
}

struct SyntheticStage {
    cfg: SyntheticPipeline,
    stage: usize,
    data_rng: Pcg32,
    /// This inner step's microbatch inputs (first & last stages only).
    xs: Vec<Vec<f32>>,
    /// c_w (used by the last stage).
    target: Vec<f32>,
    /// Last stage: a_{M-1} per in-flight micro, for the loss gradient.
    stash: HashMap<usize, Vec<f32>>,
}

impl SyntheticStage {
    fn is_first(&self) -> bool {
        self.stage == 0
    }

    fn is_last(&self) -> bool {
        self.stage == self.cfg.stages - 1
    }
}

impl StageCompute for SyntheticStage {
    fn numel(&self) -> usize {
        self.cfg.dim
    }

    fn init(&self) -> Result<Vec<f32>> {
        Ok(vec![0.0; self.cfg.dim])
    }

    fn param_spec(&self) -> Vec<ParamEntry> {
        vec![ParamEntry {
            name: format!("stage{}.w", self.stage),
            shape: vec![self.cfg.dim],
            offset: 0,
        }]
    }

    fn next_step(&mut self) -> Result<()> {
        if self.is_first() || self.is_last() {
            self.xs = (0..self.cfg.micros)
                .map(|_| {
                    let mut x = vec![0.0f32; self.cfg.dim];
                    self.data_rng.fill_normal(&mut x, 0.0, 1.0);
                    x
                })
                .collect();
        }
        Ok(())
    }

    fn forward(
        &mut self,
        params: &[f32],
        micro: usize,
        acts_in: Option<Vec<f32>>,
    ) -> Result<Option<Vec<f32>>> {
        let input: Vec<f32> = if self.is_first() {
            self.xs
                .get(micro)
                .cloned()
                .ok_or_else(|| anyhow!("micro {micro} not drawn"))?
        } else {
            acts_in.ok_or_else(|| anyhow!("mid/last stage needs acts_in"))?
        };
        let g = self.cfg.gain(self.stage);
        let a: Vec<f32> = input
            .iter()
            .zip(params)
            .map(|(x, w)| g * x + w)
            .collect();
        if self.is_last() {
            self.stash.insert(micro, a);
            Ok(None)
        } else {
            Ok(Some(a))
        }
    }

    fn backward(
        &mut self,
        _params: &[f32],
        micro: usize,
        grad_in: Option<Vec<f32>>,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>, Option<f32>)> {
        let k = self.cfg.dim as f32;
        let (g_act, loss) = if self.is_last() {
            let a = self
                .stash
                .remove(&micro)
                .ok_or_else(|| anyhow!("no stashed forward for micro {micro}"))?;
            let x = self
                .xs
                .get(micro)
                .ok_or_else(|| anyhow!("micro {micro} not drawn"))?;
            let total = self.cfg.total_gain();
            // y = (Π g)·x + c_w; loss = ½·mean((a − y)²).
            let mut loss = 0.0f64;
            let mut g = vec![0.0f32; self.cfg.dim];
            for i in 0..self.cfg.dim {
                let d = a[i] - (total * x[i] + self.target[i]);
                loss += 0.5 * (d as f64) * (d as f64);
                g[i] = d / k;
            }
            (g, Some((loss / k as f64) as f32))
        } else {
            (
                grad_in.ok_or_else(|| anyhow!("mid/first stage needs grad_in"))?,
                None,
            )
        };
        // ∂a_s/∂w_s = 1, so the param grad IS the activation grad; the
        // upstream message carries this stage's gain.
        let grads = g_act.clone();
        let upstream = if self.is_first() {
            None
        } else {
            let g = self.cfg.gain(self.stage);
            Some(g_act.iter().map(|v| g * v).collect())
        };
        Ok((grads, upstream, loss))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::faulty::{FaultPlan, FaultyRing};

    fn opts(rounds: usize, overlap: bool) -> PipelineRunOpts {
        PipelineRunOpts {
            rounds,
            local_steps: 8,
            inner_lr: 0.05,
            weight_decay: 0.0,
            outer_lr: 0.7,
            outer_momentum: 0.6,
            overlap,
            error_feedback: false,
            method: Method::None,
            seed: 1234,
        }
    }

    #[test]
    fn synthetic_grads_match_closed_form() {
        // Drive the stage computes directly (no threads): the chained
        // backward must reproduce the analytic gradient
        // ∇w_s = (Π_{j>s} g_j)·(a_last − y)/k.
        let wl = SyntheticPipeline::new(3, 2, 5, 42);
        let mut stages: Vec<Box<dyn StageCompute>> =
            (0..3).map(|s| wl.make_stage(0, s).unwrap()).collect();
        let params: Vec<Vec<f32>> = (0..3)
            .map(|s| {
                let mut p = vec![0.0f32; 5];
                Pcg32::new(7, s as u64).fill_normal(&mut p, 0.0, 0.3);
                p
            })
            .collect();
        for st in stages.iter_mut() {
            st.next_step().unwrap();
        }
        for micro in 0..2 {
            let mut acts: Option<Vec<f32>> = None;
            for s in 0..3 {
                acts = stages[s].forward(&params[s], micro, acts).unwrap();
            }
            assert!(acts.is_none(), "last stage keeps its activations");
            let (g2, up2, loss) =
                stages[2].backward(&params[2], micro, None).unwrap();
            let loss = loss.unwrap();
            assert!(loss.is_finite() && loss > 0.0);
            let (g1, up1, l1) =
                stages[1].backward(&params[1], micro, up2).unwrap();
            assert!(l1.is_none());
            let (g0, up0, _) =
                stages[0].backward(&params[0], micro, up1).unwrap();
            assert!(up0.is_none());
            // g2 is the output gradient; downstream gains scale g1, g0.
            for i in 0..5 {
                let want1 = wl.gain(2) * g2[i];
                assert!((g1[i] - want1).abs() < 1e-5, "{} vs {want1}", g1[i]);
                let want0 = wl.gain(1) * wl.gain(2) * g2[i];
                assert!((g0[i] - want0).abs() < 1e-5, "{} vs {want0}", g0[i]);
                assert!(
                    (wl.downstream_gain(0) - wl.gain(1) * wl.gain(2)).abs()
                        < 1e-6
                );
            }
        }
    }

    #[test]
    fn stage_parallel_converges_and_workers_agree() {
        let wl = SyntheticPipeline::new(3, 4, 16, 99);
        let rings = local_stage_rings(2, 3);
        let out = run_pipeline(&wl, 2, rings, &opts(5, false)).unwrap();
        assert_eq!(out.reports.len(), 2 * 3 * 5);
        assert_eq!(out.final_params.len(), 3 * 16);
        assert!(out.total_wire_bytes > 0);
        let curve = out.mean_loss_per_round();
        assert_eq!(curve.len(), 5);
        let first = curve.first().unwrap().1;
        assert!(
            out.final_eval < first * 0.5,
            "final {} vs round-1 {first}",
            out.final_eval
        );
    }

    #[test]
    fn overlap_defers_round_one_and_still_converges() {
        let wl = SyntheticPipeline::new(2, 3, 16, 7);
        let rings = local_stage_rings(2, 2);
        // One-step-delayed outer updates at high gain oscillate on this
        // fast-converging chain (each H-step block moves a large fraction
        // toward the optimum, unlike a real transformer round), so the
        // overlap tests run the outer optimizer gently.
        let mut o = opts(6, true);
        o.outer_lr = 0.3;
        o.outer_momentum = 0.3;
        let out = run_pipeline(&wl, 2, rings, &o).unwrap();
        // Round 1: nothing in flight yet — zero wire on every stage.
        assert!(out
            .reports
            .iter()
            .filter(|r| r.round == 1)
            .all(|r| r.wire_bytes == 0));
        assert!(out
            .reports
            .iter()
            .filter(|r| r.round == 2)
            .all(|r| r.wire_bytes > 0));
        let first = out.mean_loss_per_round().first().unwrap().1;
        assert!(out.final_eval < first * 0.5, "{}", out.final_eval);
    }

    #[test]
    fn single_stage_single_micro_edge_case_runs() {
        let wl = SyntheticPipeline::new(1, 1, 8, 3);
        let rings = local_stage_rings(2, 1);
        let out = run_pipeline(&wl, 2, rings, &opts(4, false)).unwrap();
        assert!(out.final_eval.is_finite());
        assert_eq!(out.final_params.len(), 8);
    }

    #[test]
    fn composes_with_fault_injecting_transport() {
        // Wrap every per-stage ring member in the seeded delay injector:
        // the executor must tolerate arbitrary collective timing.
        let wl = SyntheticPipeline::new(2, 2, 8, 11);
        let plan = FaultPlan {
            seed: 5,
            delay_prob: 0.5,
            max_delay_ms: 2,
            kill_round: 0,
            straggler_ms: 0,
            exit_on_kill: false,
        };
        let rings: Vec<Vec<Box<dyn RingTransport>>> = local_stage_rings(2, 2)
            .into_iter()
            .map(|worker| {
                worker
                    .into_iter()
                    .map(|m| {
                        Box::new(FaultyRing::new(m, plan.clone()))
                            as Box<dyn RingTransport>
                    })
                    .collect()
            })
            .collect();
        let out = run_pipeline(&wl, 2, rings, &opts(3, false)).unwrap();
        assert!(out.final_eval.is_finite());
        assert!(out.total_wire_bytes > 0);
    }

    #[test]
    fn quantized_compression_runs_per_stage() {
        let wl = SyntheticPipeline::new(2, 2, 16, 21);
        let rings = local_stage_rings(2, 2);
        let mut o = opts(4, false);
        o.method = Method::Quant { q_bits: 8 };
        o.error_feedback = true;
        let out = run_pipeline(&wl, 2, rings, &o).unwrap();
        let first = out.mean_loss_per_round().first().unwrap().1;
        assert!(out.final_eval < first, "{} vs {first}", out.final_eval);
        // int8 wire: ~1 byte/elem instead of 4.
        let per_round: u64 = out
            .reports
            .iter()
            .filter(|r| r.round == 1 && r.worker == 0)
            .map(|r| r.wire_bytes)
            .sum();
        assert!(per_round < 2 * 2 * 16, "wire {per_round}");
    }

    #[test]
    fn rejects_bad_shapes_and_methods() {
        let wl = SyntheticPipeline::new(2, 2, 4, 1);
        assert!(run_pipeline(&wl, 2, local_stage_rings(2, 1), &opts(1, false))
            .is_err());
        let mut o = opts(1, false);
        o.method = Method::TopK { ratio: 0.1, q_bits: 4 };
        assert!(run_pipeline(&wl, 2, local_stage_rings(2, 2), &o).is_err());
    }
}
