//! Stage-parallel 1F1B executor: pipeline parallelism run for real.
//!
//! Each DP cluster runs its model as `stages` stage executors — one OS
//! thread per stage — each executing its own 1F1B op stream
//! ([`super::one_f_one_b_schedule`]) in order.  Activations flow down and
//! grad-activations flow up over blocking mpsc channels, which realize
//! exactly the dependency rules that [`super::execute_streams`] encodes
//! for the validator and the DES: a stage's next op blocks until its
//! upstream forward (or downstream backward) has delivered.
//!
//! The paper's §2.2 Dual Optimizer Policy is realized literally: every
//! stage thread holds ONLY its own parameter shard plus its slice of
//! *both* optimizers (inner AdamW moments + outer Nesterov buffer — a
//! per-stage [`DualOptimizer`]), so optimizer VRAM scales down with the
//! stage count.  Outer rounds run through the shared
//! [`crate::rounds::RoundEngine`]: per-stage pseudo-gradients reduce over
//! a per-stage [`RingTransport`] ring that connects the same stage across
//! DP clusters, so PP composes with any transport backend (local mpsc,
//! TCP, fault-injecting wrappers) and with one-step-delay overlap — each
//! stage's collective runs on its own comm thread while the stage trains
//! the next H local steps.
//!
//! Workloads implement [`PipelineWorkload`]/[`StageCompute`]: the PJRT
//! artifact-backed implementation lives in [`crate::coordinator`]; the
//! [`SyntheticPipeline`] here (a depth-M affine chain with per-worker
//! targets) exercises the full executor — schedule, channels, per-stage
//! duals, ring reduction, overlap — with no artifacts at all.
//!
//! Data-bearing stages (first and last) must draw identical input
//! streams: they are constructed with the same seed and advance in
//! lockstep (one draw per inner step), so the tokens consumed at stage 0
//! and the labels consumed at the last stage always belong to the same
//! microbatch.
//!
//! # The 1F1B stream format (executor contract)
//!
//! A stage executor consumes one `Vec<Cell>` — *its own* per-stage op
//! stream from [`one_f_one_b_schedule`], validated up front by
//! [`super::validate_schedule`] — strictly in order.  For every forward
//! cell it first receives the upstream activations (unless it is stage
//! 0), runs [`StageCompute::forward`], and ships the result downstream
//! (unless it is the last stage); for every backward cell it first
//! receives the downstream grad-activations (unless last), runs
//! [`StageCompute::backward`], accumulates the parameter gradient, and
//! ships grad-activations upstream (unless stage 0).  Each message
//! carries its microbatch index and executors verify it against the
//! cell's, so a mis-ordered wire is an error, never silent corruption.
//! The blocking receive realizes exactly the dependency rules that
//! [`super::execute_streams`] encodes for the validator and the DES.
//!
//! # StageLink: wire-agnostic activation transport
//!
//! The executor speaks to its pipeline neighbors only through the
//! [`StageLink`] trait (send/recv of microbatch-indexed activations and
//! grad-activations).  Two wires implement it: [`MpscStageLink`] —
//! in-process blocking channels, used by [`run_pipeline`]'s one thread
//! per (worker, stage) — and
//! [`TcpStageLink`](crate::transport::tcp::TcpStageLink) —
//! length-delimited [`Msg::Acts`](crate::transport::frame::Msg)/`Grads`
//! frames between the one-OS-process-per-stage members of the elastic
//! fleet ([`crate::transport::elastic`]).  [`run_stream_step`] is the
//! shared inner-step driver, so both deployments execute the
//! *identical* instruction sequence (bit-for-bit parity is
//! integration-tested).

use crate::comm::ring::build_ring;
use crate::compress::Method;
use crate::optim::{AdamW, DualOptimizer};
use crate::pipeline::{one_f_one_b_schedule, validate_schedule, Cell};
use crate::rounds::driver::{EpochEnd, RoundDriver, RoundTelemetry, RoundWork};
use crate::rounds::{RingLane, RoundEngine};
use crate::runtime::manifest::ParamEntry;
use crate::transport::RingTransport;
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::Instant;

/// One pipeline stage's compute, owned by its executor thread (built
/// *inside* the thread via [`PipelineWorkload::make_stage`], so
/// implementations may hold thread-bound state like a PJRT runtime).
pub trait StageCompute {
    /// Flat parameter count of this stage.
    fn numel(&self) -> usize;
    /// Initial stage parameters.
    fn init(&self) -> Result<Vec<f32>>;
    /// Parameter layout for wire compression (a single 1-D entry is a
    /// valid fallback when the layout is opaque).
    fn param_spec(&self) -> Vec<ParamEntry>;
    /// Advance to the next inner step's data (called once per inner
    /// step, before the microbatch schedule runs).
    fn next_step(&mut self) -> Result<()>;
    /// Deterministically re-align this stage's data stream to resume at
    /// `round` (elastic churn recovery).  Under one-step-delay overlap a
    /// break can catch sibling stages a partial round apart, so
    /// data-bearing stages must re-derive their stream as a pure
    /// function of (seed, worker, round) or the first and last stage
    /// would consume mismatched microbatches after recovery.  Default:
    /// no-op (stateless stages).  Never called on the un-churned path,
    /// so threaded-vs-fleet bit parity is unaffected.
    fn reset_data(&mut self, _round: usize) -> Result<()> {
        Ok(())
    }
    /// Forward one microbatch.  `acts_in` is `None` on stage 0.  Returns
    /// the activations to ship downstream (`None` on the last stage).
    /// Implementations stash whatever their backward needs.
    fn forward(
        &mut self,
        params: &[f32],
        micro: usize,
        acts_in: Option<Vec<f32>>,
    ) -> Result<Option<Vec<f32>>>;
    /// Backward one microbatch.  `grad_in` is `None` on the last stage.
    /// Returns (parameter gradients, grad-activations to ship upstream
    /// (`None` on stage 0), microbatch loss (`Some` on the last stage)).
    fn backward(
        &mut self,
        params: &[f32],
        micro: usize,
        grad_in: Option<Vec<f32>>,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>, Option<f32>)>;
}

/// A model partitioned into pipeline stages: builds per-(worker, stage)
/// compute and evaluates assembled full parameter vectors.  `Sync`
/// because one instance is shared by reference across all stage threads.
pub trait PipelineWorkload: Sync {
    fn stages(&self) -> usize;
    /// In-flight microbatches per inner step.
    fn micros(&self) -> usize;
    fn stage_numel(&self, stage: usize) -> usize;
    fn make_stage(&self, worker: usize, stage: usize) -> Result<Box<dyn StageCompute>>;
    /// Eval loss of an assembled (stage-concatenated) parameter vector.
    fn eval(&self, full_params: &[f32]) -> Result<f32>;
}

#[derive(Clone, Debug)]
pub struct PipelineRunOpts {
    pub rounds: usize,
    /// H — inner steps per outer round.
    pub local_steps: usize,
    pub inner_lr: f32,
    pub weight_decay: f32,
    pub outer_lr: f32,
    pub outer_momentum: f32,
    /// One-step-delay overlap of the per-stage collectives (§2.3).
    pub overlap: bool,
    pub error_feedback: bool,
    /// AllReduce-compatible wire compression for the per-stage rings.
    pub method: Method,
    pub seed: u64,
    /// Persistent comm-thread pool size (1 = spawn-per-round, the
    /// historical behavior).  See [`crate::comm::pool`].
    pub comm_pool_size: usize,
    /// Reduce pipeline depth (1 = sequential per-entry reduce).  See
    /// [`crate::rounds::WireCompressor::set_pipeline_depth`].
    pub pipeline_depth: usize,
}

impl Default for PipelineRunOpts {
    fn default() -> Self {
        PipelineRunOpts {
            rounds: 4,
            local_steps: 8,
            inner_lr: 0.05,
            weight_decay: 0.0,
            outer_lr: 0.7,
            outer_momentum: 0.9,
            overlap: false,
            error_feedback: false,
            method: Method::None,
            seed: 1234,
            comm_pool_size: 1,
            pipeline_depth: 1,
        }
    }
}

/// Per-(worker, stage, round) telemetry.
#[derive(Clone, Debug)]
pub struct StageRoundReport {
    pub worker: usize,
    pub stage: usize,
    pub round: usize,
    /// Mean microbatch loss over the round (last stage only; NaN on
    /// stages that never see the labels).
    pub mean_loss: f32,
    /// Payload bytes of the reduction completed during this round (zero
    /// on the first overlap round — nothing was in flight yet).
    pub wire_bytes: u64,
    /// Measured *compute* seconds per inner step this round: time spent
    /// inside this stage's forward/backward kernels only — time blocked
    /// waiting on neighbor dataflow, the optimizer, and the ring
    /// collective are all excluded, so imbalanced stages show different
    /// numbers instead of all converging to the pipeline critical path.
    /// This is the number the DES calibration consumes — the real
    /// counterpart of the simulator's modeled per-stage step time.
    pub step_secs: f64,
}

#[derive(Debug)]
pub struct PipelineOutcome {
    pub reports: Vec<StageRoundReport>,
    pub final_eval: f32,
    /// Worker 0's assembled params (stage concatenation == the single
    /// flat layout; all workers are verified to agree).
    pub final_params: Vec<f32>,
    pub total_wire_bytes: u64,
}

/// Aggregated per-stage wall-time measurement over a whole run.
#[derive(Clone, Debug)]
pub struct StageTimeSummary {
    pub stage: usize,
    /// Number of (worker, round) samples aggregated.
    pub samples: usize,
    /// Mean measured compute seconds per inner step (kernel time only;
    /// see [`StageRoundReport::step_secs`]).
    pub mean_step_secs: f64,
    /// Slowest (worker, round) sample — the straggler bound the 1F1B
    /// critical path actually saw.
    pub max_step_secs: f64,
}

/// Aggregate raw `(stage, measured step secs)` samples into per-stage
/// summaries — shared by [`PipelineOutcome::stage_time_summary`] (local
/// threaded runs) and the elastic coordinator's heartbeat telemetry
/// (TCP fleet runs), so `coordinate --report` covers both deployments
/// with one shape.  Non-finite samples (e.g. a worker that measured
/// nothing) are dropped.
pub fn summarize_step_samples(samples: &[(u32, f64)]) -> Vec<StageTimeSummary> {
    let stages = samples
        .iter()
        .map(|&(s, _)| s as usize + 1)
        .max()
        .unwrap_or(0);
    (0..stages)
        .map(|s| {
            let vals: Vec<f64> = samples
                .iter()
                .filter(|&&(st, v)| st as usize == s && v.is_finite())
                .map(|&(_, v)| v)
                .collect();
            let n = vals.len();
            StageTimeSummary {
                stage: s,
                samples: n,
                mean_step_secs: if n > 0 {
                    vals.iter().sum::<f64>() / n as f64
                } else {
                    0.0
                },
                max_step_secs: vals.iter().cloned().fold(0.0, f64::max),
            }
        })
        .collect()
}

/// Serialize stage-time summaries for the run report JSON (the one
/// serializer shared by [`PipelineOutcome::to_json`] and the CLI report
/// writer).
pub fn stage_times_json(times: &[StageTimeSummary]) -> Json {
    Json::Arr(
        times
            .iter()
            .map(|t| {
                obj(vec![
                    ("stage", Json::Num(t.stage as f64)),
                    ("samples", Json::Num(t.samples as f64)),
                    ("mean_step_secs", Json::Num(t.mean_step_secs)),
                    ("max_step_secs", Json::Num(t.max_step_secs)),
                ])
            })
            .collect(),
    )
}

/// `Json::Num` for finite values, `Json::Null` otherwise — the writer
/// would emit a bare `NaN` literal (invalid JSON) for non-finite floats.
pub fn json_num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

impl PipelineOutcome {
    /// Measured per-stage step times aggregated over workers and rounds
    /// (the numbers the DES calibration consumes; see
    /// [`crate::sim::pipeline_step_secs`] for the modeled counterpart).
    pub fn stage_time_summary(&self) -> Vec<StageTimeSummary> {
        let samples: Vec<(u32, f64)> = self
            .reports
            .iter()
            .map(|r| (r.stage as u32, r.step_secs))
            .collect();
        summarize_step_samples(&samples)
    }

    /// Run report JSON: final eval, wire ledger, loss curve, and the
    /// measured per-stage compute times.
    pub fn to_json(&self) -> Json {
        let stage_times = stage_times_json(&self.stage_time_summary());
        let rounds = Json::Arr(
            self.mean_loss_per_round()
                .into_iter()
                .map(|(r, l)| {
                    obj(vec![
                        ("round", Json::Num(r as f64)),
                        ("mean_loss", json_num_or_null(l as f64)),
                    ])
                })
                .collect(),
        );
        obj(vec![
            ("final_eval", json_num_or_null(self.final_eval as f64)),
            ("total_wire_bytes", Json::Num(self.total_wire_bytes as f64)),
            ("rounds", rounds),
            ("stage_times", stage_times),
        ])
    }

    /// Mean last-stage loss per round across workers.
    pub fn mean_loss_per_round(&self) -> Vec<(usize, f32)> {
        let rounds = self.reports.iter().map(|r| r.round).max().unwrap_or(0);
        let mut out = Vec::new();
        for r in 1..=rounds {
            let ls: Vec<f32> = self
                .reports
                .iter()
                .filter(|x| x.round == r && !x.mean_loss.is_nan())
                .map(|x| x.mean_loss)
                .collect();
            if !ls.is_empty() {
                out.push((r, ls.iter().sum::<f32>() / ls.len() as f32));
            }
        }
        out
    }
}

/// One stage executor's view of its pipeline neighbors, independent of
/// the wire: microbatch-indexed activations flow downstream (stage s →
/// s+1), grad-activations flow upstream (s+1 → s).  Implementations:
/// [`MpscStageLink`] (in-process channels) and
/// [`TcpStageLink`](crate::transport::tcp::TcpStageLink)
/// (length-delimited frames between stage OS processes).
///
/// Contract: `has_upstream()` iff this is not stage 0, `has_downstream()`
/// iff this is not the last stage; receives block until the neighbor
/// delivers (or the wire errors — a dead neighbor must surface as `Err`,
/// never a hang, so the elastic fleet can treat it as churn).
pub trait StageLink: Send {
    /// A stage s−1 exists (this executor receives acts, sends grads).
    fn has_upstream(&self) -> bool;
    /// A stage s+1 exists (this executor sends acts, receives grads).
    fn has_downstream(&self) -> bool;
    fn send_acts(&mut self, micro: usize, acts: Vec<f32>) -> Result<()>;
    fn recv_acts(&mut self) -> Result<(usize, Vec<f32>)>;
    fn send_grads(&mut self, micro: usize, grads: Vec<f32>) -> Result<()>;
    fn recv_grads(&mut self) -> Result<(usize, Vec<f32>)>;
}

/// In-process [`StageLink`]: blocking mpsc channels between the stage
/// threads of one worker.
#[derive(Default)]
pub struct MpscStageLink {
    acts_rx: Option<mpsc::Receiver<(usize, Vec<f32>)>>,
    acts_tx: Option<mpsc::Sender<(usize, Vec<f32>)>>,
    grads_rx: Option<mpsc::Receiver<(usize, Vec<f32>)>>,
    grads_tx: Option<mpsc::Sender<(usize, Vec<f32>)>>,
}

impl StageLink for MpscStageLink {
    fn has_upstream(&self) -> bool {
        self.acts_rx.is_some()
    }

    fn has_downstream(&self) -> bool {
        self.acts_tx.is_some()
    }

    fn send_acts(&mut self, micro: usize, acts: Vec<f32>) -> Result<()> {
        self.acts_tx
            .as_ref()
            .ok_or_else(|| anyhow!("last stage has no downstream link"))?
            .send((micro, acts))
            .map_err(|_| anyhow!("downstream stage hung up"))
    }

    fn recv_acts(&mut self) -> Result<(usize, Vec<f32>)> {
        self.acts_rx
            .as_ref()
            .ok_or_else(|| anyhow!("first stage has no upstream link"))?
            .recv()
            .map_err(|_| anyhow!("upstream stage hung up"))
    }

    fn send_grads(&mut self, micro: usize, grads: Vec<f32>) -> Result<()> {
        self.grads_tx
            .as_ref()
            .ok_or_else(|| anyhow!("first stage has no upstream link"))?
            .send((micro, grads))
            .map_err(|_| anyhow!("upstream stage hung up"))
    }

    fn recv_grads(&mut self) -> Result<(usize, Vec<f32>)> {
        self.grads_rx
            .as_ref()
            .ok_or_else(|| anyhow!("last stage has no downstream link"))?
            .recv()
            .map_err(|_| anyhow!("downstream stage hung up"))
    }
}

/// Build the intra-worker chain of [`MpscStageLink`]s: element s talks to
/// s−1 and s+1.
pub fn mpsc_stage_links(stages: usize) -> Vec<MpscStageLink> {
    let mut links: Vec<MpscStageLink> =
        (0..stages).map(|_| MpscStageLink::default()).collect();
    for b in 0..stages.saturating_sub(1) {
        let (ta, ra) = mpsc::channel();
        links[b].acts_tx = Some(ta);
        links[b + 1].acts_rx = Some(ra);
        let (tg, rg) = mpsc::channel();
        links[b + 1].grads_tx = Some(tg);
        links[b].grads_rx = Some(rg);
    }
    links
}

/// Drive ONE inner step's 1F1B op stream over a stage link: receive and
/// ship activations / grad-activations per the stream order, accumulate
/// this stage's parameter gradient into `grad_acc` (summed over
/// microbatches, *not* yet divided), and return the (loss sum, loss
/// count, compute seconds) of the step — compute seconds covers only the
/// time inside [`StageCompute::forward`]/[`StageCompute::backward`], so
/// per-stage balance is visible instead of every stage reporting the
/// pipeline critical path.  Shared by the local threaded executor and
/// the elastic TCP stage workers so both run the identical instruction
/// sequence.
pub fn run_stream_step(
    compute: &mut dyn StageCompute,
    params: &[f32],
    stream: &[Cell],
    link: &mut dyn StageLink,
    grad_acc: &mut [f32],
) -> Result<(f64, usize, f64)> {
    let n = grad_acc.len();
    let mut loss_acc = 0.0f64;
    let mut loss_n = 0usize;
    let mut busy_secs = 0.0f64;
    for cell in stream {
        if cell.is_forward {
            let acts_in = if link.has_upstream() {
                let _s = crate::obs::span("pipeline", "link.acts");
                let (mi, a) = link.recv_acts()?;
                if mi != cell.micro {
                    return Err(anyhow!(
                        "acts for micro {mi}, expected {}",
                        cell.micro
                    ));
                }
                Some(a)
            } else {
                None
            };
            let t0 = Instant::now();
            let out = {
                let _s = crate::obs::span("pipeline", "fwd");
                compute.forward(params, cell.micro, acts_in)?
            };
            busy_secs += t0.elapsed().as_secs_f64();
            if link.has_downstream() {
                let a = out.ok_or_else(|| {
                    anyhow!("stage {} produced no activations", cell.stage)
                })?;
                link.send_acts(cell.micro, a)?;
            }
        } else {
            let grad_in = if link.has_downstream() {
                let _s = crate::obs::span("pipeline", "link.grads");
                let (mi, g) = link.recv_grads()?;
                if mi != cell.micro {
                    return Err(anyhow!(
                        "grads for micro {mi}, expected {}",
                        cell.micro
                    ));
                }
                Some(g)
            } else {
                None
            };
            let t0 = Instant::now();
            let (gp, gout, loss) = {
                let _s = crate::obs::span("pipeline", "bwd");
                compute.backward(params, cell.micro, grad_in)?
            };
            busy_secs += t0.elapsed().as_secs_f64();
            if gp.len() != n {
                return Err(anyhow!("stage grad len {} != numel {n}", gp.len()));
            }
            for (a, b) in grad_acc.iter_mut().zip(&gp) {
                *a += b;
            }
            if link.has_upstream() {
                let g = gout.ok_or_else(|| {
                    anyhow!("stage {} produced no upstream grads", cell.stage)
                })?;
                link.send_grads(cell.micro, g)?;
            }
            if let Some(l) = loss {
                loss_acc += l as f64;
                loss_n += 1;
            }
        }
    }
    Ok((loss_acc, loss_n, busy_secs))
}

/// One stage executor's local work for the shared round driver
/// ([`crate::rounds::driver::RoundDriver`]): H inner steps of this
/// stage's 1F1B stream over a [`StageLink`], each followed by one
/// per-stage inner AdamW step.  Used by BOTH the threaded executor
/// (`stage_main`) and the elastic stage fleet
/// ([`crate::transport::elastic::run_stage_worker`]) so the two
/// deployments execute the identical instruction sequence — the fleet
/// swaps `link` per membership epoch, the threaded path never does.
pub struct StageStepWork {
    pub compute: Box<dyn StageCompute>,
    pub stream: Vec<Cell>,
    pub link: Box<dyn StageLink>,
    pub params: Vec<f32>,
    pub inner: AdamW,
    pub micros: usize,
}

impl RoundWork for StageStepWork {
    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, p: &[f32]) {
        self.params.copy_from_slice(p);
    }

    fn local_round(&mut self, h: usize) -> Result<(f32, f64)> {
        let n = self.params.len();
        let mut loss_acc = 0.0f64;
        let mut loss_n = 0usize;
        let mut busy_secs = 0.0f64;
        for _ in 0..h {
            self.compute.next_step()?;
            let mut grad_acc = vec![0.0f32; n];
            // A dead neighbor surfaces here (link timeout / EOF): churn
            // for the elastic fleet, a hard error for the threaded path.
            let (ls, ln, busy) = run_stream_step(
                self.compute.as_mut(),
                &self.params,
                &self.stream,
                self.link.as_mut(),
                &mut grad_acc,
            )?;
            loss_acc += ls;
            loss_n += ln;
            busy_secs += busy;
            // Mean gradient over microbatches, one inner AdamW step.
            let inv = 1.0 / self.micros as f32;
            grad_acc.iter_mut().for_each(|g| *g *= inv);
            self.inner.step(&mut self.params, &grad_acc);
        }
        let loss = if loss_n > 0 {
            (loss_acc / loss_n as f64) as f32
        } else {
            f32::NAN
        };
        Ok((loss, busy_secs / h.max(1) as f64))
    }
}

/// Build the per-stage DP rings over the local mpsc backend:
/// `rings[worker][stage]` — stage s of every worker shares one ring.
pub fn local_stage_rings(dp: usize, stages: usize) -> Vec<Vec<Box<dyn RingTransport>>> {
    let mut rings: Vec<Vec<Box<dyn RingTransport>>> =
        (0..dp).map(|_| Vec::with_capacity(stages)).collect();
    for _s in 0..stages {
        for (w, m) in build_ring(dp).into_iter().enumerate() {
            rings[w].push(Box::new(m));
        }
    }
    rings
}

/// Run `opts.rounds` outer rounds of stage-parallel 1F1B training:
/// `dp × stages` executor threads, per-stage dual optimizers, per-stage
/// ring reduction of pseudo-gradients through the shared round engine.
pub fn run_pipeline(
    workload: &dyn PipelineWorkload,
    dp: usize,
    rings: Vec<Vec<Box<dyn RingTransport>>>,
    opts: &PipelineRunOpts,
) -> Result<PipelineOutcome> {
    let m = workload.stages();
    let micros = workload.micros();
    if dp == 0 || m == 0 {
        return Err(anyhow!("need at least one worker and one stage"));
    }
    if micros == 0 {
        return Err(anyhow!("need at least one microbatch"));
    }
    if rings.len() != dp || rings.iter().any(|r| r.len() != m) {
        return Err(anyhow!(
            "ring shape mismatch: want {dp} workers x {m} stages"
        ));
    }
    if !opts.method.allreduce_compatible() {
        return Err(anyhow!(
            "stage-parallel path needs AllReduce-compatible compression"
        ));
    }
    let streams = one_f_one_b_schedule(m, micros);
    validate_schedule(&streams, micros)
        .map_err(|e| anyhow!("invalid 1F1B schedule: {e}"))?;

    let (tx_report, rx_report) = mpsc::channel::<StageRoundReport>();
    let results: Vec<Result<(Vec<f32>, u64)>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(dp * m);
        for (w, worker_rings) in rings.into_iter().enumerate() {
            // Intra-worker links: acts flow s -> s+1, grads s+1 -> s.
            let links = mpsc_stage_links(m);
            for (s, (link, ring)) in
                links.into_iter().zip(worker_rings).enumerate()
            {
                let stream = streams[s].clone();
                let tx = tx_report.clone();
                handles.push(scope.spawn(move || {
                    stage_main(
                        workload,
                        w,
                        s,
                        Box::new(link),
                        ring,
                        opts,
                        stream,
                        tx,
                    )
                    .with_context(|| format!("worker {w} stage {s}"))
                }));
            }
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    drop(tx_report);

    let mut reports: Vec<StageRoundReport> = rx_report.into_iter().collect();
    reports.sort_by_key(|r| (r.round, r.worker, r.stage));

    // Assemble per-worker full vectors (stage order == single layout).
    let mut stage_params: Vec<Vec<f32>> = Vec::with_capacity(dp * m);
    let mut total_wire = 0u64;
    for r in results {
        let (p, wire) = r?;
        total_wire += wire;
        stage_params.push(p);
    }
    let mut assembled: Vec<Vec<f32>> = Vec::with_capacity(dp);
    for w in 0..dp {
        let mut full = Vec::new();
        for s in 0..m {
            full.extend_from_slice(&stage_params[w * m + s]);
        }
        assembled.push(full);
    }
    // Every worker must agree (per-stage ring algebra is symmetric);
    // verify instead of trusting.
    let p0 = &assembled[0];
    for pi in &assembled[1..] {
        let max_dev = p0
            .iter()
            .zip(pi)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        if max_dev > 1e-4 {
            return Err(anyhow!("workers diverged: max param dev {max_dev}"));
        }
    }
    let final_eval = workload.eval(p0)?;
    Ok(PipelineOutcome {
        reports,
        final_eval,
        final_params: assembled.swap_remove(0),
        total_wire_bytes: total_wire,
    })
}

/// One stage executor thread: run the 1F1B stream for H inner steps per
/// round, step the per-stage dual optimizer, and close each round through
/// the shared outer-round engine over this stage's DP ring — all via the
/// single epoch-aware [`RoundDriver`] (one epoch here: the threaded
/// executor has no membership churn, so a broken wire is a hard error).
#[allow(clippy::too_many_arguments)]
fn stage_main(
    workload: &dyn PipelineWorkload,
    worker: usize,
    stage: usize,
    link: Box<dyn StageLink>,
    ring: Box<dyn RingTransport>,
    opts: &PipelineRunOpts,
    stream: Vec<Cell>,
    tx_report: mpsc::Sender<StageRoundReport>,
) -> Result<(Vec<f32>, u64)> {
    crate::obs::set_scope(worker as u32, stage as u32);
    let compute = workload.make_stage(worker, stage)?;
    let n = compute.numel();
    let params = compute.init()?;
    if params.len() != n {
        return Err(anyhow!("init len {} != numel {n}", params.len()));
    }
    let micros = workload.micros();

    // §2.2: this thread holds only this stage's optimizer pair.
    let DualOptimizer { inner, outer } = DualOptimizer::new(
        n,
        opts.inner_lr,
        opts.weight_decay,
        opts.outer_lr,
        opts.outer_momentum,
    );
    let engine = RoundEngine::new(
        params.clone(),
        1,
        outer,
        opts.overlap,
        opts.error_feedback,
    );
    // Per-stage compressor seed: identical on every worker (the ring
    // peers must derive the same low-rank bases), decorrelated across
    // stages; stage 0 reduces exactly like the single-stage path.
    let stage_seed =
        opts.seed ^ (stage as u64).wrapping_mul(0x9e3779b97f4a7c15);
    let spec = compute.param_spec();
    crate::comm::pool::configure(opts.comm_pool_size);
    let mut lane =
        RingLane::new(ring, opts.method.clone(), stage_seed, spec, opts.overlap);
    lane.set_pipeline_depth(opts.pipeline_depth);
    lane.set_use_pool(opts.comm_pool_size >= 2);

    let mut work =
        StageStepWork { compute, stream, link, params, inner, micros };
    let mut driver = RoundDriver::new(engine, lane, opts.rounds, opts.local_steps);
    let end = driver.run_rounds(1, &mut work, &mut |t: RoundTelemetry| {
        tx_report
            .send(StageRoundReport {
                worker,
                stage,
                round: t.round,
                mean_loss: t.loss,
                wire_bytes: t.wire_bytes,
                step_secs: t.step_secs,
            })
            .ok();
    })?;
    if let EpochEnd::Broken(e) = end {
        return Err(e.context("stage ring broke in the threaded executor"));
    }
    // Trailing in-flight reduction (overlap flush at shutdown).
    driver.finish(&mut work)?;
    Ok((work.params, driver.wire_total()))
}

// ---------------------------------------------------------------------------
// Synthetic multi-stage workload (no artifacts)
// ---------------------------------------------------------------------------

/// Artifact-free depth-M affine chain with per-worker targets:
///
/// ```text
/// a_0 = g_0·x + w_0,   a_s = g_s·a_{s-1} + w_s   (elementwise, dim k)
/// loss = ½·mean((a_{M-1} − y)²),   y = (Π g_s)·x + c_w
/// ```
///
/// where `g_s` are fixed per-stage gains and `c_w = c_shared + 0.1·n_w`
/// is each worker's displaced target (the heterogeneous-shard setup of
/// the elastic quadratic workload, stretched over a real pipeline).  The
/// optimum is realizable, gradients are stage-dependent (each stage's
/// grad carries its downstream gain product, so mis-routed grads are
/// caught), and eval has a closed form: the input term cancels, leaving
/// `½·mean((Σ_s (Π_{j>s} g_j)·w_s − c_shared)²)`.
#[derive(Clone, Debug)]
pub struct SyntheticPipeline {
    pub stages: usize,
    pub micros: usize,
    /// Activation / per-stage parameter dimension k.
    pub dim: usize,
    pub seed: u64,
}

impl SyntheticPipeline {
    pub fn new(stages: usize, micros: usize, dim: usize, seed: u64) -> Self {
        assert!(stages >= 1 && micros >= 1 && dim >= 1);
        SyntheticPipeline { stages, micros, dim, seed }
    }

    /// Per-stage gain g_s in [0.85, 1.15] — stage-dependent so gradient
    /// routing errors change the numbers.
    fn gain(&self, s: usize) -> f32 {
        0.85 + 0.3 * (s as f32 + 1.0) / self.stages as f32
    }

    /// Π_{j>s} g_j — the factor a stage's parameter carries to the output.
    fn downstream_gain(&self, s: usize) -> f32 {
        (s + 1..self.stages).map(|j| self.gain(j)).product()
    }

    /// Π over all stages (the input's path to the output).
    fn total_gain(&self) -> f32 {
        (0..self.stages).map(|s| self.gain(s)).product()
    }

    fn shared_target(&self) -> Vec<f32> {
        let mut c = vec![0.0f32; self.dim];
        Pcg32::new(self.seed ^ 0x7a67, 0).fill_normal(&mut c, 0.0, 1.0);
        c
    }

    fn worker_target(&self, worker: usize) -> Vec<f32> {
        let shared = self.shared_target();
        let mut noise = vec![0.0f32; self.dim];
        Pcg32::new(self.seed ^ 0x7a67, 1 + worker as u64)
            .fill_normal(&mut noise, 0.0, 1.0);
        shared
            .iter()
            .zip(&noise)
            .map(|(s, n)| s + 0.1 * n)
            .collect()
    }
}

impl PipelineWorkload for SyntheticPipeline {
    fn stages(&self) -> usize {
        self.stages
    }

    fn micros(&self) -> usize {
        self.micros
    }

    fn stage_numel(&self, _stage: usize) -> usize {
        self.dim
    }

    fn make_stage(&self, worker: usize, stage: usize) -> Result<Box<dyn StageCompute>> {
        if stage >= self.stages {
            return Err(anyhow!("stage {stage} out of range"));
        }
        Ok(Box::new(SyntheticStage {
            cfg: self.clone(),
            stage,
            worker,
            // First and last stage draw the IDENTICAL input stream.
            data_rng: Pcg32::new(self.seed ^ 0xda7a, worker as u64),
            xs: Vec::new(),
            target: self.worker_target(worker),
            stash: HashMap::new(),
        }))
    }

    fn eval(&self, full_params: &[f32]) -> Result<f32> {
        if full_params.len() != self.stages * self.dim {
            return Err(anyhow!(
                "assembled params len {} != {}",
                full_params.len(),
                self.stages * self.dim
            ));
        }
        // Effective output bias Σ_s (Π_{j>s} g_j)·w_s vs the shared
        // target; the input term cancels exactly (see type docs).
        let shared = self.shared_target();
        let mut acc = 0.0f64;
        for i in 0..self.dim {
            let mut eff = 0.0f32;
            for s in 0..self.stages {
                eff += self.downstream_gain(s)
                    * full_params[s * self.dim + i];
            }
            let d = (eff - shared[i]) as f64;
            acc += d * d;
        }
        Ok((0.5 * acc / self.dim as f64) as f32)
    }
}

struct SyntheticStage {
    cfg: SyntheticPipeline,
    stage: usize,
    worker: usize,
    data_rng: Pcg32,
    /// This inner step's microbatch inputs (first & last stages only).
    xs: Vec<Vec<f32>>,
    /// c_w (used by the last stage).
    target: Vec<f32>,
    /// Last stage: a_{M-1} per in-flight micro, for the loss gradient.
    stash: HashMap<usize, Vec<f32>>,
}

impl SyntheticStage {
    fn is_first(&self) -> bool {
        self.stage == 0
    }

    fn is_last(&self) -> bool {
        self.stage == self.cfg.stages - 1
    }
}

impl StageCompute for SyntheticStage {
    fn numel(&self) -> usize {
        self.cfg.dim
    }

    fn init(&self) -> Result<Vec<f32>> {
        Ok(vec![0.0; self.cfg.dim])
    }

    fn param_spec(&self) -> Vec<ParamEntry> {
        vec![ParamEntry {
            name: format!("stage{}.w", self.stage),
            shape: vec![self.cfg.dim],
            offset: 0,
        }]
    }

    fn next_step(&mut self) -> Result<()> {
        if self.is_first() || self.is_last() {
            self.xs = (0..self.cfg.micros)
                .map(|_| {
                    let mut x = vec![0.0f32; self.cfg.dim];
                    self.data_rng.fill_normal(&mut x, 0.0, 1.0);
                    x
                })
                .collect();
        }
        Ok(())
    }

    fn reset_data(&mut self, round: usize) -> Result<()> {
        // Pure function of (seed, worker, round): the first and last
        // stage of one cluster re-derive the IDENTICAL stream no matter
        // where churn caught each of them mid-round.
        self.data_rng = Pcg32::new(
            self.cfg.seed
                ^ 0xda7a
                ^ (round as u64).wrapping_mul(0x9e3779b97f4a7c15),
            self.worker as u64,
        );
        self.xs.clear();
        self.stash.clear();
        Ok(())
    }

    fn forward(
        &mut self,
        params: &[f32],
        micro: usize,
        acts_in: Option<Vec<f32>>,
    ) -> Result<Option<Vec<f32>>> {
        let input: Vec<f32> = if self.is_first() {
            self.xs
                .get(micro)
                .cloned()
                .ok_or_else(|| anyhow!("micro {micro} not drawn"))?
        } else {
            acts_in.ok_or_else(|| anyhow!("mid/last stage needs acts_in"))?
        };
        let g = self.cfg.gain(self.stage);
        let a: Vec<f32> = input
            .iter()
            .zip(params)
            .map(|(x, w)| g * x + w)
            .collect();
        if self.is_last() {
            self.stash.insert(micro, a);
            Ok(None)
        } else {
            Ok(Some(a))
        }
    }

    fn backward(
        &mut self,
        _params: &[f32],
        micro: usize,
        grad_in: Option<Vec<f32>>,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>, Option<f32>)> {
        let k = self.cfg.dim as f32;
        let (g_act, loss) = if self.is_last() {
            let a = self
                .stash
                .remove(&micro)
                .ok_or_else(|| anyhow!("no stashed forward for micro {micro}"))?;
            let x = self
                .xs
                .get(micro)
                .ok_or_else(|| anyhow!("micro {micro} not drawn"))?;
            let total = self.cfg.total_gain();
            // y = (Π g)·x + c_w; loss = ½·mean((a − y)²).
            let mut loss = 0.0f64;
            let mut g = vec![0.0f32; self.cfg.dim];
            for i in 0..self.cfg.dim {
                let d = a[i] - (total * x[i] + self.target[i]);
                loss += 0.5 * (d as f64) * (d as f64);
                g[i] = d / k;
            }
            (g, Some((loss / k as f64) as f32))
        } else {
            (
                grad_in.ok_or_else(|| anyhow!("mid/first stage needs grad_in"))?,
                None,
            )
        };
        // ∂a_s/∂w_s = 1, so the param grad IS the activation grad; the
        // upstream message carries this stage's gain.
        let grads = g_act.clone();
        let upstream = if self.is_first() {
            None
        } else {
            let g = self.cfg.gain(self.stage);
            Some(g_act.iter().map(|v| g * v).collect())
        };
        Ok((grads, upstream, loss))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::faulty::{FaultPlan, FaultyRing};

    fn opts(rounds: usize, overlap: bool) -> PipelineRunOpts {
        PipelineRunOpts {
            rounds,
            local_steps: 8,
            inner_lr: 0.05,
            weight_decay: 0.0,
            outer_lr: 0.7,
            outer_momentum: 0.6,
            overlap,
            error_feedback: false,
            method: Method::None,
            seed: 1234,
            comm_pool_size: 1,
            pipeline_depth: 1,
        }
    }

    #[test]
    fn synthetic_grads_match_closed_form() {
        // Drive the stage computes directly (no threads): the chained
        // backward must reproduce the analytic gradient
        // ∇w_s = (Π_{j>s} g_j)·(a_last − y)/k.
        let wl = SyntheticPipeline::new(3, 2, 5, 42);
        let mut stages: Vec<Box<dyn StageCompute>> =
            (0..3).map(|s| wl.make_stage(0, s).unwrap()).collect();
        let params: Vec<Vec<f32>> = (0..3)
            .map(|s| {
                let mut p = vec![0.0f32; 5];
                Pcg32::new(7, s as u64).fill_normal(&mut p, 0.0, 0.3);
                p
            })
            .collect();
        for st in stages.iter_mut() {
            st.next_step().unwrap();
        }
        for micro in 0..2 {
            let mut acts: Option<Vec<f32>> = None;
            for s in 0..3 {
                acts = stages[s].forward(&params[s], micro, acts).unwrap();
            }
            assert!(acts.is_none(), "last stage keeps its activations");
            let (g2, up2, loss) =
                stages[2].backward(&params[2], micro, None).unwrap();
            let loss = loss.unwrap();
            assert!(loss.is_finite() && loss > 0.0);
            let (g1, up1, l1) =
                stages[1].backward(&params[1], micro, up2).unwrap();
            assert!(l1.is_none());
            let (g0, up0, _) =
                stages[0].backward(&params[0], micro, up1).unwrap();
            assert!(up0.is_none());
            // g2 is the output gradient; downstream gains scale g1, g0.
            for i in 0..5 {
                let want1 = wl.gain(2) * g2[i];
                assert!((g1[i] - want1).abs() < 1e-5, "{} vs {want1}", g1[i]);
                let want0 = wl.gain(1) * wl.gain(2) * g2[i];
                assert!((g0[i] - want0).abs() < 1e-5, "{} vs {want0}", g0[i]);
                assert!(
                    (wl.downstream_gain(0) - wl.gain(1) * wl.gain(2)).abs()
                        < 1e-6
                );
            }
        }
    }

    #[test]
    fn stage_parallel_converges_and_workers_agree() {
        let wl = SyntheticPipeline::new(3, 4, 16, 99);
        let rings = local_stage_rings(2, 3);
        let out = run_pipeline(&wl, 2, rings, &opts(5, false)).unwrap();
        assert_eq!(out.reports.len(), 2 * 3 * 5);
        assert_eq!(out.final_params.len(), 3 * 16);
        assert!(out.total_wire_bytes > 0);
        // Per-stage wall-time telemetry: one summary per stage, fed by
        // every (worker, round) sample, with sane mean ≤ max ordering.
        let times = out.stage_time_summary();
        assert_eq!(times.len(), 3);
        for t in &times {
            assert_eq!(t.samples, 2 * 5);
            assert!(t.mean_step_secs >= 0.0);
            assert!(t.max_step_secs >= t.mean_step_secs);
        }
        // The run report JSON round-trips through the parser.
        let j = out.to_json();
        let parsed =
            crate::util::json::Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.path("stage_times").unwrap().as_arr().unwrap().len(),
            3
        );
        assert!(parsed.path("final_eval").unwrap().as_f64().is_some());
        let curve = out.mean_loss_per_round();
        assert_eq!(curve.len(), 5);
        let first = curve.first().unwrap().1;
        assert!(
            out.final_eval < first * 0.5,
            "final {} vs round-1 {first}",
            out.final_eval
        );
    }

    #[test]
    fn overlap_defers_round_one_and_still_converges() {
        let wl = SyntheticPipeline::new(2, 3, 16, 7);
        let rings = local_stage_rings(2, 2);
        // One-step-delayed outer updates at high gain oscillate on this
        // fast-converging chain (each H-step block moves a large fraction
        // toward the optimum, unlike a real transformer round), so the
        // overlap tests run the outer optimizer gently.
        let mut o = opts(6, true);
        o.outer_lr = 0.3;
        o.outer_momentum = 0.3;
        let out = run_pipeline(&wl, 2, rings, &o).unwrap();
        // Round 1: nothing in flight yet — zero wire on every stage.
        assert!(out
            .reports
            .iter()
            .filter(|r| r.round == 1)
            .all(|r| r.wire_bytes == 0));
        assert!(out
            .reports
            .iter()
            .filter(|r| r.round == 2)
            .all(|r| r.wire_bytes > 0));
        let first = out.mean_loss_per_round().first().unwrap().1;
        assert!(out.final_eval < first * 0.5, "{}", out.final_eval);
    }

    #[test]
    fn single_stage_single_micro_edge_case_runs() {
        let wl = SyntheticPipeline::new(1, 1, 8, 3);
        let rings = local_stage_rings(2, 1);
        let out = run_pipeline(&wl, 2, rings, &opts(4, false)).unwrap();
        assert!(out.final_eval.is_finite());
        assert_eq!(out.final_params.len(), 8);
    }

    #[test]
    fn composes_with_fault_injecting_transport() {
        // Wrap every per-stage ring member in the seeded delay injector:
        // the executor must tolerate arbitrary collective timing.
        let wl = SyntheticPipeline::new(2, 2, 8, 11);
        let plan = FaultPlan {
            seed: 5,
            delay_prob: 0.5,
            max_delay_ms: 2,
            kill_round: 0,
            break_round: 0,
            straggler_ms: 0,
            exit_on_kill: false,
        };
        let rings: Vec<Vec<Box<dyn RingTransport>>> = local_stage_rings(2, 2)
            .into_iter()
            .map(|worker| {
                worker
                    .into_iter()
                    .map(|m| {
                        Box::new(FaultyRing::new(m, plan.clone()))
                            as Box<dyn RingTransport>
                    })
                    .collect()
            })
            .collect();
        let out = run_pipeline(&wl, 2, rings, &opts(3, false)).unwrap();
        assert!(out.final_eval.is_finite());
        assert!(out.total_wire_bytes > 0);
    }

    #[test]
    fn quantized_compression_runs_per_stage() {
        let wl = SyntheticPipeline::new(2, 2, 16, 21);
        let rings = local_stage_rings(2, 2);
        let mut o = opts(4, false);
        o.method = Method::Quant { q_bits: 8 };
        o.error_feedback = true;
        let out = run_pipeline(&wl, 2, rings, &o).unwrap();
        let first = out.mean_loss_per_round().first().unwrap().1;
        assert!(out.final_eval < first, "{} vs {first}", out.final_eval);
        // int8 wire: ~1 byte/elem instead of 4.
        let per_round: u64 = out
            .reports
            .iter()
            .filter(|r| r.round == 1 && r.worker == 0)
            .map(|r| r.wire_bytes)
            .sum();
        assert!(per_round < 2 * 2 * 16, "wire {per_round}");
    }

    #[test]
    fn mpsc_links_route_acts_and_grads_by_micro() {
        let mut links = mpsc_stage_links(2);
        let mut l1 = links.pop().unwrap();
        let mut l0 = links.pop().unwrap();
        assert!(!l0.has_upstream() && l0.has_downstream());
        assert!(l1.has_upstream() && !l1.has_downstream());
        l0.send_acts(0, vec![1.0]).unwrap();
        assert_eq!(l1.recv_acts().unwrap(), (0, vec![1.0]));
        l1.send_grads(0, vec![2.0]).unwrap();
        assert_eq!(l0.recv_grads().unwrap(), (0, vec![2.0]));
        // Endpoint misuse is an error, not a hang.
        assert!(l0.recv_acts().is_err());
        assert!(l1.send_acts(0, vec![0.0]).is_err());
    }

    #[test]
    fn rejects_bad_shapes_and_methods() {
        let wl = SyntheticPipeline::new(2, 2, 4, 1);
        assert!(run_pipeline(&wl, 2, local_stage_rings(2, 1), &opts(1, false))
            .is_err());
        let mut o = opts(1, false);
        o.method = Method::TopK { ratio: 0.1, q_bits: 4 };
        assert!(run_pipeline(&wl, 2, local_stage_rings(2, 2), &o).is_err());
    }
}
