//! Stage-parallel pipeline executor: microbatch schedules run for real.
//!
//! Each DP cluster runs its model as `stages` stage executors — one OS
//! thread per executor — each executing its own op stream (any
//! [`super::ScheduleKind`]: GPipe, 1F1B, interleaved virtual stages, or
//! zero-bubble) in order.  Activations flow down and grad-activations
//! flow up over blocking mpsc channels, which realize exactly the
//! dependency rules that [`super::execute_streams`] encodes for the
//! validator and the DES: a stage's next op blocks until its upstream
//! forward (or downstream backward) has delivered.
//!
//! The paper's §2.2 Dual Optimizer Policy is realized literally: every
//! stage thread holds ONLY its own parameter shard plus its slice of
//! *both* optimizers (inner AdamW moments + outer Nesterov buffer — a
//! per-stage [`DualOptimizer`]), so optimizer VRAM scales down with the
//! stage count.  Outer rounds run through the shared
//! [`crate::rounds::RoundEngine`]: per-stage pseudo-gradients reduce over
//! a per-stage [`RingTransport`] ring that connects the same stage across
//! DP clusters, so PP composes with any transport backend (local mpsc,
//! TCP, fault-injecting wrappers) and with one-step-delay overlap — each
//! stage's collective runs on its own comm thread while the stage trains
//! the next H local steps.
//!
//! # Virtual stages (interleaved schedules)
//!
//! Under `virtual_stages = v > 1` an executor owns `v` model *chunks*:
//! chunk c on executor s is model stage `c·S + s`, so consecutive chunks
//! wrap from executor S−1 back to executor 0 (the Megatron virtual
//! pipeline layout).  The executor holds per-chunk [`StageCompute`]s and
//! parameter shards ([`StageChunk`]) concatenated into one flat vector —
//! one inner AdamW, one round engine, one lane — while the DP reduction
//! stays *per model stage*: [`ChunkedRing`] splits the concatenated
//! pseudo-gradient at chunk boundaries and reduces each slice over the
//! (stage, chunk) ring, so an interleaved run is bit-for-bit identical
//! to the same model run un-interleaved.
//!
//! # Split backward (zero-bubble schedules)
//!
//! Zero-bubble streams carry `B` (input-grad) and `W` (weight-grad)
//! cells.  Computes that implement
//! [`StageCompute::backward_input`]/[`StageCompute::backward_weight`]
//! (and report [`StageCompute::supports_split_backward`]) run them
//! separately — the upstream stage unblocks after the cheap input-grad
//! half.  Computes that can't split (the PJRT artifact path) fall back
//! transparently: the fused backward runs at the `B` cell and the `W`
//! cell just collects the already-computed weight gradient, so every
//! workload runs every schedule.
//!
//! # The stream format (executor contract)
//!
//! A stage executor consumes one `Vec<Cell>` — *its own* per-stage op
//! stream, validated up front by [`super::validate_schedule`] — strictly
//! in order.  Messages carry (chunk, micro) tags; receives route through
//! a stash so an executor interleaving two chunks never mis-binds a
//! frame, and a mis-tagged wire is an error, never silent corruption.
//! Gradient accumulation is per-(chunk, micro) slots summed in a fixed
//! order after the stream completes, so every schedule — whatever order
//! its backwards ran in — produces bit-identical gradients.
//!
//! # StageLink: wire-agnostic activation transport
//!
//! The executor speaks to its pipeline neighbors only through the
//! [`StageLink`] trait (send/recv of (chunk, micro)-tagged activations
//! and grad-activations).  Two wires implement it: [`MpscStageLink`] —
//! in-process blocking channels, used by [`run_pipeline`]'s one thread
//! per (worker, stage) — and
//! [`TcpStageLink`](crate::transport::tcp::TcpStageLink) —
//! length-delimited [`Msg::Acts`](crate::transport::frame::Msg)/`Grads`
//! frames between the one-OS-process-per-stage members of the elastic
//! fleet ([`crate::transport::elastic`]).  [`run_stream_step`] is the
//! shared inner-step driver, so both deployments execute the
//! *identical* instruction sequence (bit-for-bit parity is
//! integration-tested).

use crate::comm::ring::build_ring;
use crate::compress::Method;
use crate::optim::{AdamW, DualOptimizer};
use crate::pipeline::{validate_schedule, Cell, OpKind, ScheduleKind};
use crate::rounds::driver::{EpochEnd, RoundDriver, RoundTelemetry, RoundWork};
use crate::rounds::{RingLane, RoundEngine};
use crate::runtime::manifest::ParamEntry;
use crate::transport::{ByteMeter, RingTransport};
use crate::util::json::{obj, Json};
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc;
use std::time::Instant;

/// One pipeline stage's compute, owned by its executor thread (built
/// *inside* the thread via [`PipelineWorkload::make_stage`], so
/// implementations may hold thread-bound state like a PJRT runtime).
pub trait StageCompute {
    /// Flat parameter count of this stage.
    fn numel(&self) -> usize;
    /// Initial stage parameters.
    fn init(&self) -> Result<Vec<f32>>;
    /// Parameter layout for wire compression (a single 1-D entry is a
    /// valid fallback when the layout is opaque).
    fn param_spec(&self) -> Vec<ParamEntry>;
    /// Advance to the next inner step's data (called once per inner
    /// step, before the microbatch schedule runs).
    fn next_step(&mut self) -> Result<()>;
    /// Deterministically re-align this stage's data stream to resume at
    /// `round` (elastic churn recovery).  Under one-step-delay overlap a
    /// break can catch sibling stages a partial round apart, so
    /// data-bearing stages must re-derive their stream as a pure
    /// function of (seed, worker, round) or the first and last stage
    /// would consume mismatched microbatches after recovery.  Default:
    /// no-op (stateless stages).  Never called on the un-churned path,
    /// so threaded-vs-fleet bit parity is unaffected.
    fn reset_data(&mut self, _round: usize) -> Result<()> {
        Ok(())
    }
    /// Forward one microbatch.  `acts_in` is `None` on stage 0.  Returns
    /// the activations to ship downstream (`None` on the last stage).
    /// Implementations stash whatever their backward needs.
    fn forward(
        &mut self,
        params: &[f32],
        micro: usize,
        acts_in: Option<Vec<f32>>,
    ) -> Result<Option<Vec<f32>>>;
    /// Fused backward one microbatch.  `grad_in` is `None` on the last
    /// stage.  Returns (parameter gradients, grad-activations to ship
    /// upstream (`None` on stage 0), microbatch loss (`Some` on the last
    /// stage)).
    fn backward(
        &mut self,
        params: &[f32],
        micro: usize,
        grad_in: Option<Vec<f32>>,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>, Option<f32>)>;
    /// True when this compute implements the split backward
    /// ([`Self::backward_input`] + [`Self::backward_weight`]).  The
    /// executor uses the fused [`Self::backward`] fallback on zero-bubble
    /// schedules otherwise.
    fn supports_split_backward(&self) -> bool {
        false
    }
    /// Input-grad half of a split backward: everything the *upstream*
    /// stage is waiting for.  Returns (grad-activations to ship upstream
    /// (`None` on stage 0), microbatch loss (`Some` on the last stage)).
    /// The weight gradient must be deferred to
    /// [`Self::backward_weight`].
    fn backward_input(
        &mut self,
        _params: &[f32],
        micro: usize,
        _grad_in: Option<Vec<f32>>,
    ) -> Result<(Option<Vec<f32>>, Option<f32>)> {
        Err(anyhow!("split backward unsupported (micro {micro})"))
    }
    /// Weight-grad half of a split backward for a microbatch whose
    /// [`Self::backward_input`] already ran.  Returns the parameter
    /// gradients.
    fn backward_weight(&mut self, _params: &[f32], micro: usize) -> Result<Vec<f32>> {
        Err(anyhow!("split backward unsupported (micro {micro})"))
    }
}

/// A model partitioned into pipeline stages: builds per-(worker, stage)
/// compute and evaluates assembled full parameter vectors.  `Sync`
/// because one instance is shared by reference across all stage threads.
pub trait PipelineWorkload: Sync {
    /// Number of *model* stages (= executors × virtual stages).
    fn stages(&self) -> usize;
    /// In-flight microbatches per inner step.
    fn micros(&self) -> usize;
    fn stage_numel(&self, stage: usize) -> usize;
    fn make_stage(&self, worker: usize, stage: usize) -> Result<Box<dyn StageCompute>>;
    /// Eval loss of an assembled (stage-concatenated) parameter vector.
    fn eval(&self, full_params: &[f32]) -> Result<f32>;
}

#[derive(Clone, Debug)]
pub struct PipelineRunOpts {
    pub rounds: usize,
    /// H — inner steps per outer round.
    pub local_steps: usize,
    pub inner_lr: f32,
    pub weight_decay: f32,
    pub outer_lr: f32,
    pub outer_momentum: f32,
    /// One-step-delay overlap of the per-stage collectives (§2.3).
    pub overlap: bool,
    pub error_feedback: bool,
    /// AllReduce-compatible wire compression for the per-stage rings.
    pub method: Method,
    pub seed: u64,
    /// Persistent comm-thread pool size (1 = spawn-per-round, the
    /// historical behavior).  See [`crate::comm::pool`].
    pub comm_pool_size: usize,
    /// Reduce pipeline depth (1 = sequential per-entry reduce).  See
    /// [`crate::rounds::WireCompressor::set_pipeline_depth`].
    pub pipeline_depth: usize,
    /// Microbatch schedule the stage executors run.
    pub schedule: ScheduleKind,
    /// Model chunks per executor (> 1 only with the interleaved
    /// schedule); must divide [`PipelineWorkload::stages`].
    pub virtual_stages: usize,
}

impl Default for PipelineRunOpts {
    fn default() -> Self {
        PipelineRunOpts {
            rounds: 4,
            local_steps: 8,
            inner_lr: 0.05,
            weight_decay: 0.0,
            outer_lr: 0.7,
            outer_momentum: 0.9,
            overlap: false,
            error_feedback: false,
            method: Method::None,
            seed: 1234,
            comm_pool_size: 1,
            pipeline_depth: 1,
            schedule: ScheduleKind::OneFOneB,
            virtual_stages: 1,
        }
    }
}

/// Per-(worker, stage, round) telemetry.
#[derive(Clone, Debug)]
pub struct StageRoundReport {
    pub worker: usize,
    pub stage: usize,
    pub round: usize,
    /// Mean microbatch loss over the round (last stage only; NaN on
    /// stages that never see the labels).
    pub mean_loss: f32,
    /// Payload bytes of the reduction completed during this round (zero
    /// on the first overlap round — nothing was in flight yet).
    pub wire_bytes: u64,
    /// Measured *compute* seconds per inner step this round: time spent
    /// inside this stage's forward/backward kernels only — time blocked
    /// waiting on neighbor dataflow, the optimizer, and the ring
    /// collective are all excluded, so imbalanced stages show different
    /// numbers instead of all converging to the pipeline critical path.
    /// This is the number the DES calibration consumes — the real
    /// counterpart of the simulator's modeled per-stage step time.
    pub step_secs: f64,
}

#[derive(Debug)]
pub struct PipelineOutcome {
    pub reports: Vec<StageRoundReport>,
    pub final_eval: f32,
    /// Worker 0's assembled params (model-stage concatenation == the
    /// single flat layout; all workers are verified to agree).
    pub final_params: Vec<f32>,
    pub total_wire_bytes: u64,
}

/// Aggregated per-stage wall-time measurement over a whole run.
#[derive(Clone, Debug)]
pub struct StageTimeSummary {
    pub stage: usize,
    /// Number of (worker, round) samples aggregated.
    pub samples: usize,
    /// Mean measured compute seconds per inner step (kernel time only;
    /// see [`StageRoundReport::step_secs`]).
    pub mean_step_secs: f64,
    /// Slowest (worker, round) sample — the straggler bound the
    /// schedule's critical path actually saw.
    pub max_step_secs: f64,
}

/// Aggregate raw `(stage, measured step secs)` samples into per-stage
/// summaries — shared by [`PipelineOutcome::stage_time_summary`] (local
/// threaded runs) and the elastic coordinator's heartbeat telemetry
/// (TCP fleet runs), so `coordinate --report` covers both deployments
/// with one shape.  Non-finite samples (e.g. a worker that measured
/// nothing) are dropped.
pub fn summarize_step_samples(samples: &[(u32, f64)]) -> Vec<StageTimeSummary> {
    let stages = samples
        .iter()
        .map(|&(s, _)| s as usize + 1)
        .max()
        .unwrap_or(0);
    (0..stages)
        .map(|s| {
            let vals: Vec<f64> = samples
                .iter()
                .filter(|&&(st, v)| st as usize == s && v.is_finite())
                .map(|&(_, v)| v)
                .collect();
            let n = vals.len();
            StageTimeSummary {
                stage: s,
                samples: n,
                mean_step_secs: if n > 0 {
                    vals.iter().sum::<f64>() / n as f64
                } else {
                    0.0
                },
                max_step_secs: vals.iter().cloned().fold(0.0, f64::max),
            }
        })
        .collect()
}

/// Serialize stage-time summaries for the run report JSON (the one
/// serializer shared by [`PipelineOutcome::to_json`] and the CLI report
/// writer).
pub fn stage_times_json(times: &[StageTimeSummary]) -> Json {
    Json::Arr(
        times
            .iter()
            .map(|t| {
                obj(vec![
                    ("stage", Json::Num(t.stage as f64)),
                    ("samples", Json::Num(t.samples as f64)),
                    ("mean_step_secs", Json::Num(t.mean_step_secs)),
                    ("max_step_secs", Json::Num(t.max_step_secs)),
                ])
            })
            .collect(),
    )
}

/// `Json::Num` for finite values, `Json::Null` otherwise — the writer
/// would emit a bare `NaN` literal (invalid JSON) for non-finite floats.
pub fn json_num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

impl PipelineOutcome {
    /// Measured per-stage step times aggregated over workers and rounds
    /// (the numbers the DES calibration consumes; see
    /// [`crate::sim::pipeline_step_secs`] for the modeled counterpart).
    pub fn stage_time_summary(&self) -> Vec<StageTimeSummary> {
        let samples: Vec<(u32, f64)> = self
            .reports
            .iter()
            .map(|r| (r.stage as u32, r.step_secs))
            .collect();
        summarize_step_samples(&samples)
    }

    /// Run report JSON: final eval, wire ledger, loss curve, and the
    /// measured per-stage compute times.
    pub fn to_json(&self) -> Json {
        let stage_times = stage_times_json(&self.stage_time_summary());
        let rounds = Json::Arr(
            self.mean_loss_per_round()
                .into_iter()
                .map(|(r, l)| {
                    obj(vec![
                        ("round", Json::Num(r as f64)),
                        ("mean_loss", json_num_or_null(l as f64)),
                    ])
                })
                .collect(),
        );
        obj(vec![
            ("final_eval", json_num_or_null(self.final_eval as f64)),
            ("total_wire_bytes", Json::Num(self.total_wire_bytes as f64)),
            ("rounds", rounds),
            ("stage_times", stage_times),
        ])
    }

    /// Mean last-stage loss per round across workers.
    pub fn mean_loss_per_round(&self) -> Vec<(usize, f32)> {
        let rounds = self.reports.iter().map(|r| r.round).max().unwrap_or(0);
        let mut out = Vec::new();
        for r in 1..=rounds {
            let ls: Vec<f32> = self
                .reports
                .iter()
                .filter(|x| x.round == r && !x.mean_loss.is_nan())
                .map(|x| x.mean_loss)
                .collect();
            if !ls.is_empty() {
                out.push((r, ls.iter().sum::<f32>() / ls.len() as f32));
            }
        }
        out
    }
}

/// One stage executor's view of its pipeline neighbors, independent of
/// the wire: (chunk, micro)-tagged activations flow downstream (stage s
/// → s+1, wrapping S−1 → 0 between virtual-stage chunks), grad-
/// activations flow upstream.  Implementations: [`MpscStageLink`]
/// (in-process channels) and
/// [`TcpStageLink`](crate::transport::tcp::TcpStageLink)
/// (length-delimited frames between stage OS processes).
///
/// Contract: `has_upstream()`/`has_downstream()` report whether the
/// corresponding wire exists (chained links omit them at the pipeline
/// ends; ring links for interleaved schedules always have both);
/// receives block until the neighbor delivers (or the wire errors — a
/// dead neighbor must surface as `Err`, never a hang, so the elastic
/// fleet can treat it as churn).
pub trait StageLink: Send {
    /// A producer of activations exists (stage s−1, or stage S−1 via the
    /// virtual-stage wrap link).
    fn has_upstream(&self) -> bool;
    /// A consumer of activations exists (stage s+1, or stage 0 via the
    /// virtual-stage wrap link).
    fn has_downstream(&self) -> bool;
    fn send_acts(&mut self, chunk: usize, micro: usize, acts: Vec<f32>) -> Result<()>;
    fn recv_acts(&mut self) -> Result<(usize, usize, Vec<f32>)>;
    fn send_grads(&mut self, chunk: usize, micro: usize, grads: Vec<f32>) -> Result<()>;
    fn recv_grads(&mut self) -> Result<(usize, usize, Vec<f32>)>;
}

type TaggedPayload = (usize, usize, Vec<f32>);

/// In-process [`StageLink`]: blocking mpsc channels between the stage
/// threads of one worker.
#[derive(Default)]
pub struct MpscStageLink {
    acts_rx: Option<mpsc::Receiver<TaggedPayload>>,
    acts_tx: Option<mpsc::Sender<TaggedPayload>>,
    grads_rx: Option<mpsc::Receiver<TaggedPayload>>,
    grads_tx: Option<mpsc::Sender<TaggedPayload>>,
}

impl StageLink for MpscStageLink {
    fn has_upstream(&self) -> bool {
        self.acts_rx.is_some()
    }

    fn has_downstream(&self) -> bool {
        self.acts_tx.is_some()
    }

    fn send_acts(&mut self, chunk: usize, micro: usize, acts: Vec<f32>) -> Result<()> {
        self.acts_tx
            .as_ref()
            .ok_or_else(|| anyhow!("last stage has no downstream link"))?
            .send((chunk, micro, acts))
            .map_err(|_| anyhow!("downstream stage hung up"))
    }

    fn recv_acts(&mut self) -> Result<TaggedPayload> {
        self.acts_rx
            .as_ref()
            .ok_or_else(|| anyhow!("first stage has no upstream link"))?
            .recv()
            .map_err(|_| anyhow!("upstream stage hung up"))
    }

    fn send_grads(&mut self, chunk: usize, micro: usize, grads: Vec<f32>) -> Result<()> {
        self.grads_tx
            .as_ref()
            .ok_or_else(|| anyhow!("first stage has no upstream link"))?
            .send((chunk, micro, grads))
            .map_err(|_| anyhow!("upstream stage hung up"))
    }

    fn recv_grads(&mut self) -> Result<TaggedPayload> {
        self.grads_rx
            .as_ref()
            .ok_or_else(|| anyhow!("last stage has no downstream link"))?
            .recv()
            .map_err(|_| anyhow!("downstream stage hung up"))
    }
}

/// Build the intra-worker chain of [`MpscStageLink`]s: element s talks to
/// s−1 and s+1; the pipeline ends have no wrap (plain schedules).
pub fn mpsc_stage_links(stages: usize) -> Vec<MpscStageLink> {
    let mut links: Vec<MpscStageLink> =
        (0..stages).map(|_| MpscStageLink::default()).collect();
    for b in 0..stages.saturating_sub(1) {
        wire_pair(&mut links, b, b + 1);
    }
    links
}

/// Build the intra-worker *ring* of [`MpscStageLink`]s: like
/// [`mpsc_stage_links`] plus the wrap link S−1 → 0 that interleaved
/// virtual-stage schedules need (chunk c ends on executor S−1 and chunk
/// c+1 begins on executor 0).  With one executor the link loops to
/// itself.
pub fn mpsc_stage_links_ring(stages: usize) -> Vec<MpscStageLink> {
    let mut links: Vec<MpscStageLink> =
        (0..stages).map(|_| MpscStageLink::default()).collect();
    for b in 0..stages {
        wire_pair(&mut links, b, (b + 1) % stages);
    }
    links
}

fn wire_pair(links: &mut [MpscStageLink], from: usize, to: usize) {
    let (ta, ra) = mpsc::channel();
    links[from].acts_tx = Some(ta);
    links[to].acts_rx = Some(ra);
    let (tg, rg) = mpsc::channel();
    links[to].grads_tx = Some(tg);
    links[from].grads_rx = Some(rg);
}

/// DP ring for an executor owning several virtual-stage chunks: splits
/// each all-reduce at the chunk parameter boundaries and reduces every
/// slice over that chunk's own sub-ring, so the floating-point schedule
/// is bit-identical to running the chunks as separate executors.  Built
/// with either one sub-ring per chunk (threaded executor: the
/// per-(stage, chunk) rings) or a single shared sub-ring used for every
/// slice in turn (elastic stage processes: one TCP ring per stage) — the
/// reduce algebra is identical either way because each slice's
/// collective sees the same lengths, ranks, and hop order.  Buffers
/// whose length is not the concatenated parameter size (compressed
/// payloads, pipelined segments) are reduced whole over the first
/// sub-ring.
pub struct ChunkedRing {
    subs: Vec<Box<dyn RingTransport>>,
    sizes: Vec<usize>,
    meter: ByteMeter,
}

impl ChunkedRing {
    /// `subs` is one ring per chunk, or exactly one shared ring.
    pub fn new(subs: Vec<Box<dyn RingTransport>>, sizes: Vec<usize>) -> Result<Self> {
        if subs.is_empty() || sizes.is_empty() {
            return Err(anyhow!("chunked ring needs >= 1 sub-ring and chunk"));
        }
        if subs.len() != 1 && subs.len() != sizes.len() {
            return Err(anyhow!(
                "chunked ring: {} sub-rings for {} chunks",
                subs.len(),
                sizes.len()
            ));
        }
        let (r, c) = (subs[0].rank(), subs[0].size());
        if subs.iter().any(|s| s.rank() != r || s.size() != c) {
            return Err(anyhow!("chunked ring sub-rings disagree on rank/size"));
        }
        Ok(ChunkedRing { subs, sizes, meter: ByteMeter::default() })
    }

    fn sub_for(&mut self, chunk: usize) -> &mut Box<dyn RingTransport> {
        let i = if self.subs.len() == 1 { 0 } else { chunk };
        &mut self.subs[i]
    }
}

impl RingTransport for ChunkedRing {
    fn rank(&self) -> usize {
        self.subs[0].rank()
    }

    fn size(&self) -> usize {
        self.subs[0].size()
    }

    fn send_next(&mut self, chunk: &[f32]) -> Result<()> {
        self.subs[0].send_next(chunk)
    }

    fn recv_prev(&mut self) -> Result<Vec<f32>> {
        self.subs[0].recv_prev()
    }

    fn meter(&self) -> &ByteMeter {
        &self.meter
    }

    fn begin_round(&mut self, round: usize) -> Result<()> {
        for s in self.subs.iter_mut() {
            s.begin_round(round)?;
        }
        Ok(())
    }

    fn recycle(&mut self, buf: Vec<f32>) {
        self.subs[0].recycle(buf);
    }

    fn allreduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        let before: u64 = self.subs.iter().map(|s| s.meter().total()).sum();
        let total: usize = self.sizes.iter().sum();
        let res = if buf.len() == total && self.sizes.len() > 1 {
            let mut off = 0usize;
            let sizes = self.sizes.clone();
            for (c, n) in sizes.into_iter().enumerate() {
                let (lo, hi) = (off, off + n);
                self.sub_for(c).allreduce_sum(&mut buf[lo..hi])?;
                off = hi;
            }
            Ok(())
        } else {
            self.subs[0].allreduce_sum(buf)
        };
        let after: u64 = self.subs.iter().map(|s| s.meter().total()).sum();
        // Mirror the sub-ring traffic onto this ring's own meter (the
        // lane reads wire bytes from here).
        self.meter.add(after.saturating_sub(before));
        res
    }
}

/// One virtual-stage chunk owned by a stage executor: the compute for
/// model stage `chunk·S + stage` plus its slice [offset, offset+numel)
/// of the executor's concatenated parameter vector.
pub struct StageChunk {
    pub compute: Box<dyn StageCompute>,
    pub offset: usize,
    pub numel: usize,
}

/// Route a (chunk, micro)-tagged receive: deliver the wanted payload,
/// stashing any frames for other (chunk, micro) pairs until their cell
/// comes up.  Out-of-order arrival is expected when an executor
/// interleaves chunks; a *duplicate* tag is a wire error.
fn recv_routed(
    stash: &mut HashMap<(usize, usize), Vec<f32>>,
    chunk: usize,
    micro: usize,
    what: &str,
    mut recv: impl FnMut() -> Result<TaggedPayload>,
) -> Result<Vec<f32>> {
    if let Some(p) = stash.remove(&(chunk, micro)) {
        return Ok(p);
    }
    loop {
        let (c, m, p) = recv()?;
        if c == chunk && m == micro {
            return Ok(p);
        }
        if stash.insert((c, m), p).is_some() {
            return Err(anyhow!("duplicate {what} frame for chunk {c} micro {m}"));
        }
    }
}

/// Drive ONE inner step's op stream over a stage link: receive and ship
/// activations / grad-activations per the stream order, accumulate this
/// executor's parameter gradient into `grad_acc` (summed over
/// microbatches in fixed (chunk, micro) order — *not* yet divided), and
/// return the (loss sum, loss count, compute seconds) of the step —
/// compute seconds covers only the time inside the
/// [`StageCompute`] forward/backward calls, so per-stage balance is
/// visible instead of every stage reporting the pipeline critical path.
/// `stages` is the executor count S (cells address model stage
/// `chunk·S + stage`).  Shared by the local threaded executor and the
/// elastic TCP stage workers so both run the identical instruction
/// sequence.
pub fn run_stream_step(
    chunks: &mut [StageChunk],
    params: &[f32],
    stream: &[Cell],
    stages: usize,
    link: &mut dyn StageLink,
    grad_acc: &mut [f32],
) -> Result<(f64, usize, f64)> {
    let k_total = stages * chunks.len();
    let split = stream.iter().any(|c| c.op == OpKind::W);
    let mut loss_acc = 0.0f64;
    let mut loss_n = 0usize;
    let mut busy_secs = 0.0f64;
    // Out-of-order frame stashes and per-(chunk, micro) gradient slots.
    let mut acts_stash: HashMap<(usize, usize), Vec<f32>> = HashMap::new();
    let mut grads_stash: HashMap<(usize, usize), Vec<f32>> = HashMap::new();
    let mut pending_w: HashMap<(usize, usize), Vec<f32>> = HashMap::new();
    let mut slots: BTreeMap<(usize, usize), Vec<f32>> = BTreeMap::new();
    for cell in stream {
        let chunk = chunks
            .get_mut(cell.chunk)
            .ok_or_else(|| anyhow!("cell chunk {} out of range", cell.chunk))?;
        let pslice = &params[chunk.offset..chunk.offset + chunk.numel];
        let k = cell.model_stage(stages);
        match cell.op {
            OpKind::F => {
                let acts_in = if k > 0 {
                    if !link.has_upstream() {
                        return Err(anyhow!(
                            "model stage {k} needs an upstream link"
                        ));
                    }
                    let _s = crate::obs::span("pipeline", "link.acts");
                    Some(recv_routed(
                        &mut acts_stash,
                        cell.chunk,
                        cell.micro,
                        "acts",
                        || link.recv_acts(),
                    )?)
                } else {
                    None
                };
                let t0 = Instant::now();
                let out = {
                    let _s = crate::obs::span("pipeline", "fwd");
                    chunk.compute.forward(pslice, cell.micro, acts_in)?
                };
                busy_secs += t0.elapsed().as_secs_f64();
                if k + 1 < k_total {
                    let a = out.ok_or_else(|| {
                        anyhow!("model stage {k} produced no activations")
                    })?;
                    // Tag with the RECEIVER's chunk id so routing keys
                    // match the consumer's own cells.
                    link.send_acts((k + 1) / stages, cell.micro, a)?;
                }
            }
            OpKind::B => {
                let grad_in = if k + 1 < k_total {
                    if !link.has_downstream() {
                        return Err(anyhow!(
                            "model stage {k} needs a downstream link"
                        ));
                    }
                    let _s = crate::obs::span("pipeline", "link.grads");
                    Some(recv_routed(
                        &mut grads_stash,
                        cell.chunk,
                        cell.micro,
                        "grads",
                        || link.recv_grads(),
                    )?)
                } else {
                    None
                };
                let t0 = Instant::now();
                let (gp, gout, loss) = {
                    let _s = crate::obs::span("pipeline", "bwd");
                    if split && chunk.compute.supports_split_backward() {
                        let (gout, loss) = chunk
                            .compute
                            .backward_input(pslice, cell.micro, grad_in)?;
                        (None, gout, loss)
                    } else {
                        let (gp, gout, loss) =
                            chunk.compute.backward(pslice, cell.micro, grad_in)?;
                        (Some(gp), gout, loss)
                    }
                };
                busy_secs += t0.elapsed().as_secs_f64();
                if let Some(gp) = gp {
                    if gp.len() != chunk.numel {
                        return Err(anyhow!(
                            "stage grad len {} != numel {}",
                            gp.len(),
                            chunk.numel
                        ));
                    }
                    if split {
                        // Fused fallback on a split schedule: hold the
                        // weight grad for this (chunk, micro)'s W cell.
                        pending_w.insert((cell.chunk, cell.micro), gp);
                    } else {
                        slots.insert((cell.chunk, cell.micro), gp);
                    }
                }
                if k > 0 {
                    if !link.has_upstream() {
                        return Err(anyhow!(
                            "model stage {k} needs an upstream link"
                        ));
                    }
                    let g = gout.ok_or_else(|| {
                        anyhow!("model stage {k} produced no upstream grads")
                    })?;
                    link.send_grads((k - 1) / stages, cell.micro, g)?;
                }
                if let Some(l) = loss {
                    loss_acc += l as f64;
                    loss_n += 1;
                }
            }
            OpKind::W => {
                let t0 = Instant::now();
                let gp = {
                    let _s = crate::obs::span("pipeline", "wgrad");
                    if chunk.compute.supports_split_backward() {
                        chunk.compute.backward_weight(pslice, cell.micro)?
                    } else {
                        // The fused fallback already computed it at the
                        // B cell; the W cell just collects.
                        pending_w
                            .remove(&(cell.chunk, cell.micro))
                            .ok_or_else(|| {
                                anyhow!(
                                    "W cell for chunk {} micro {} has no \
                                     pending fused backward",
                                    cell.chunk,
                                    cell.micro
                                )
                            })?
                    }
                };
                busy_secs += t0.elapsed().as_secs_f64();
                if gp.len() != chunk.numel {
                    return Err(anyhow!(
                        "stage grad len {} != numel {}",
                        gp.len(),
                        chunk.numel
                    ));
                }
                slots.insert((cell.chunk, cell.micro), gp);
            }
        }
    }
    // Fixed (chunk, micro) accumulation order: every schedule — whatever
    // order its backwards ran in — sums the same floats the same way.
    for ((c, _m), gp) in slots {
        let off = chunks[c].offset;
        for (a, b) in grad_acc[off..off + gp.len()].iter_mut().zip(&gp) {
            *a += b;
        }
    }
    Ok((loss_acc, loss_n, busy_secs))
}

/// One stage executor's local work for the shared round driver
/// ([`crate::rounds::driver::RoundDriver`]): H inner steps of this
/// executor's op stream over a [`StageLink`], each followed by one inner
/// AdamW step over the concatenated chunk parameters.  Used by BOTH the
/// threaded executor (`stage_main`) and the elastic stage fleet
/// ([`crate::transport::elastic::run_stage_worker`]) so the two
/// deployments execute the identical instruction sequence — the fleet
/// swaps `link` per membership epoch, the threaded path never does.
pub struct StageStepWork {
    pub chunks: Vec<StageChunk>,
    pub stream: Vec<Cell>,
    pub link: Box<dyn StageLink>,
    pub params: Vec<f32>,
    pub inner: AdamW,
    pub micros: usize,
    /// Executor count S (cells address model stage `chunk·S + stage`).
    pub stages: usize,
}

impl StageStepWork {
    /// Wrap a single compute (no virtual stages) — the historical shape.
    pub fn single(
        compute: Box<dyn StageCompute>,
        stream: Vec<Cell>,
        link: Box<dyn StageLink>,
        params: Vec<f32>,
        inner: AdamW,
        micros: usize,
        stages: usize,
    ) -> Self {
        let numel = compute.numel();
        StageStepWork {
            chunks: vec![StageChunk { compute, offset: 0, numel }],
            stream,
            link,
            params,
            inner,
            micros,
            stages,
        }
    }
}

impl RoundWork for StageStepWork {
    fn params(&self) -> &[f32] {
        &self.params
    }

    fn set_params(&mut self, p: &[f32]) {
        self.params.copy_from_slice(p);
    }

    fn local_round(&mut self, h: usize) -> Result<(f32, f64)> {
        let n = self.params.len();
        let mut loss_acc = 0.0f64;
        let mut loss_n = 0usize;
        let mut busy_secs = 0.0f64;
        for _ in 0..h {
            for c in self.chunks.iter_mut() {
                c.compute.next_step()?;
            }
            let mut grad_acc = vec![0.0f32; n];
            // A dead neighbor surfaces here (link timeout / EOF): churn
            // for the elastic fleet, a hard error for the threaded path.
            let (ls, ln, busy) = run_stream_step(
                &mut self.chunks,
                &self.params,
                &self.stream,
                self.stages,
                self.link.as_mut(),
                &mut grad_acc,
            )?;
            loss_acc += ls;
            loss_n += ln;
            busy_secs += busy;
            // Mean gradient over microbatches, one inner AdamW step.
            let inv = 1.0 / self.micros as f32;
            grad_acc.iter_mut().for_each(|g| *g *= inv);
            self.inner.step(&mut self.params, &grad_acc);
        }
        let loss = if loss_n > 0 {
            (loss_acc / loss_n as f64) as f32
        } else {
            f32::NAN
        };
        Ok((loss, busy_secs / h.max(1) as f64))
    }
}

/// Build the per-model-stage DP rings over the local mpsc backend:
/// `rings[worker][model_stage]` — model stage k of every worker shares
/// one ring (executors with virtual stages group v of them through
/// [`ChunkedRing`]).
pub fn local_stage_rings(dp: usize, stages: usize) -> Vec<Vec<Box<dyn RingTransport>>> {
    let mut rings: Vec<Vec<Box<dyn RingTransport>>> =
        (0..dp).map(|_| Vec::with_capacity(stages)).collect();
    for _s in 0..stages {
        for (w, m) in build_ring(dp).into_iter().enumerate() {
            rings[w].push(Box::new(m));
        }
    }
    rings
}

/// Run `opts.rounds` outer rounds of stage-parallel training under
/// `opts.schedule`: `dp × (stages / virtual_stages)` executor threads,
/// per-executor dual optimizers over concatenated chunk params,
/// per-model-stage ring reduction of pseudo-gradients through the shared
/// round engine.  `rings[worker]` carries one ring per *model* stage.
pub fn run_pipeline(
    workload: &dyn PipelineWorkload,
    dp: usize,
    rings: Vec<Vec<Box<dyn RingTransport>>>,
    opts: &PipelineRunOpts,
) -> Result<PipelineOutcome> {
    let k_total = workload.stages();
    let micros = workload.micros();
    if dp == 0 || k_total == 0 {
        return Err(anyhow!("need at least one worker and one stage"));
    }
    if micros == 0 {
        return Err(anyhow!("need at least one microbatch"));
    }
    let v = opts.virtual_stages.max(1);
    if k_total % v != 0 {
        return Err(anyhow!(
            "{k_total} model stages not divisible by {v} virtual stages"
        ));
    }
    let execs = k_total / v;
    if rings.len() != dp || rings.iter().any(|r| r.len() != k_total) {
        return Err(anyhow!(
            "ring shape mismatch: want {dp} workers x {k_total} model stages"
        ));
    }
    if !opts.method.allreduce_compatible() {
        return Err(anyhow!(
            "stage-parallel path needs AllReduce-compatible compression"
        ));
    }
    let streams = opts
        .schedule
        .streams(execs, v, micros)
        .map_err(|e| anyhow!("schedule: {e}"))?;
    validate_schedule(&streams, micros)
        .map_err(|e| anyhow!("invalid {} schedule: {e}", opts.schedule.name()))?;

    let (tx_report, rx_report) = mpsc::channel::<StageRoundReport>();
    let results: Vec<Result<(Vec<f32>, u64)>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(dp * execs);
        for (w, worker_rings) in rings.into_iter().enumerate() {
            // Intra-worker links: acts flow s -> s+1, grads s+1 -> s;
            // virtual stages add the wrap link S−1 -> 0.
            let links = if v > 1 {
                mpsc_stage_links_ring(execs)
            } else {
                mpsc_stage_links(execs)
            };
            // Regroup this worker's per-model-stage rings by executor:
            // executor s owns model stages {c·S + s}.
            let mut per_exec: Vec<Vec<Box<dyn RingTransport>>> =
                (0..execs).map(|_| Vec::with_capacity(v)).collect();
            for (k, ring) in worker_rings.into_iter().enumerate() {
                per_exec[k % execs].push(ring);
            }
            for (s, (link, exec_rings)) in
                links.into_iter().zip(per_exec).enumerate()
            {
                let ring: Box<dyn RingTransport> = if v > 1 {
                    let sizes: Vec<usize> = (0..v)
                        .map(|c| workload.stage_numel(c * execs + s))
                        .collect();
                    Box::new(ChunkedRing::new(exec_rings, sizes)?)
                } else {
                    exec_rings.into_iter().next().unwrap()
                };
                let stream = streams[s].clone();
                let tx = tx_report.clone();
                handles.push(scope.spawn(move || {
                    stage_main(
                        workload,
                        w,
                        s,
                        v,
                        Box::new(link),
                        ring,
                        opts,
                        stream,
                        tx,
                    )
                    .with_context(|| format!("worker {w} stage {s}"))
                }));
            }
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    drop(tx_report);

    let mut reports: Vec<StageRoundReport> = rx_report.into_iter().collect();
    reports.sort_by_key(|r| (r.round, r.worker, r.stage));

    // Assemble per-worker full vectors in model-stage order: executor
    // s's concat holds [chunk 0 | chunk 1 | ...] = model stages
    // {s, S+s, 2S+s, ...}.
    let mut exec_params: Vec<Vec<f32>> = Vec::with_capacity(dp * execs);
    let mut total_wire = 0u64;
    for r in results {
        let (p, wire) = r?;
        total_wire += wire;
        exec_params.push(p);
    }
    let mut assembled: Vec<Vec<f32>> = Vec::with_capacity(dp);
    for w in 0..dp {
        let mut full = Vec::new();
        for k in 0..k_total {
            let (s, c) = (k % execs, k / execs);
            let off: usize = (0..c)
                .map(|cc| workload.stage_numel(cc * execs + s))
                .sum();
            let n = workload.stage_numel(k);
            full.extend_from_slice(&exec_params[w * execs + s][off..off + n]);
        }
        assembled.push(full);
    }
    // Every worker must agree (per-stage ring algebra is symmetric);
    // verify instead of trusting.
    let p0 = &assembled[0];
    for pi in &assembled[1..] {
        let max_dev = p0
            .iter()
            .zip(pi)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        if max_dev > 1e-4 {
            return Err(anyhow!("workers diverged: max param dev {max_dev}"));
        }
    }
    let final_eval = workload.eval(p0)?;
    Ok(PipelineOutcome {
        reports,
        final_eval,
        final_params: assembled.swap_remove(0),
        total_wire_bytes: total_wire,
    })
}

/// One stage executor thread: run the schedule stream for H inner steps
/// per round over this executor's v chunk computes, step the
/// per-executor dual optimizer, and close each round through the shared
/// outer-round engine over this executor's DP ring — all via the single
/// epoch-aware [`RoundDriver`] (one epoch here: the threaded executor
/// has no membership churn, so a broken wire is a hard error).
#[allow(clippy::too_many_arguments)]
fn stage_main(
    workload: &dyn PipelineWorkload,
    worker: usize,
    stage: usize,
    virtual_stages: usize,
    link: Box<dyn StageLink>,
    ring: Box<dyn RingTransport>,
    opts: &PipelineRunOpts,
    stream: Vec<Cell>,
    tx_report: mpsc::Sender<StageRoundReport>,
) -> Result<(Vec<f32>, u64)> {
    crate::obs::set_scope(worker as u32, stage as u32);
    let execs = workload.stages() / virtual_stages;
    let micros = workload.micros();
    // Build this executor's chunk computes (model stage c·S + s) and the
    // concatenated parameter vector + wire spec.
    let mut chunks: Vec<StageChunk> = Vec::with_capacity(virtual_stages);
    let mut params: Vec<f32> = Vec::new();
    let mut spec: Vec<ParamEntry> = Vec::new();
    for c in 0..virtual_stages {
        let compute = workload.make_stage(worker, c * execs + stage)?;
        let numel = compute.numel();
        let init = compute.init()?;
        if init.len() != numel {
            return Err(anyhow!("init len {} != numel {numel}", init.len()));
        }
        let offset = params.len();
        for mut e in compute.param_spec() {
            e.offset += offset;
            spec.push(e);
        }
        params.extend_from_slice(&init);
        chunks.push(StageChunk { compute, offset, numel });
    }
    let n = params.len();

    // §2.2: this thread holds only this executor's optimizer pair.
    let DualOptimizer { inner, outer } = DualOptimizer::new(
        n,
        opts.inner_lr,
        opts.weight_decay,
        opts.outer_lr,
        opts.outer_momentum,
    );
    let engine = RoundEngine::new(
        params.clone(),
        1,
        outer,
        opts.overlap,
        opts.error_feedback,
    );
    // Per-stage compressor seed: identical on every worker (the ring
    // peers must derive the same low-rank bases), decorrelated across
    // stages; stage 0 reduces exactly like the single-stage path.
    let stage_seed =
        opts.seed ^ (stage as u64).wrapping_mul(0x9e3779b97f4a7c15);
    crate::comm::pool::configure(opts.comm_pool_size);
    let mut lane =
        RingLane::new(ring, opts.method.clone(), stage_seed, spec, opts.overlap);
    lane.set_pipeline_depth(opts.pipeline_depth);
    lane.set_use_pool(opts.comm_pool_size >= 2);

    let mut work = StageStepWork {
        chunks,
        stream,
        link,
        params,
        inner,
        micros,
        stages: execs,
    };
    let mut driver = RoundDriver::new(engine, lane, opts.rounds, opts.local_steps);
    let end = driver.run_rounds(1, &mut work, &mut |t: RoundTelemetry| {
        tx_report
            .send(StageRoundReport {
                worker,
                stage,
                round: t.round,
                mean_loss: t.loss,
                wire_bytes: t.wire_bytes,
                step_secs: t.step_secs,
            })
            .ok();
    })?;
    if let EpochEnd::Broken(e) = end {
        return Err(e.context("stage ring broke in the threaded executor"));
    }
    // Trailing in-flight reduction (overlap flush at shutdown).
    driver.finish(&mut work)?;
    Ok((work.params, driver.wire_total()))
}

// ---------------------------------------------------------------------------
// Synthetic multi-stage workload (no artifacts)
// ---------------------------------------------------------------------------

/// Artifact-free depth-M affine chain with per-worker targets:
///
/// ```text
/// a_0 = g_0·x + w_0,   a_s = g_s·a_{s-1} + w_s   (elementwise, dim k)
/// loss = ½·mean((a_{M-1} − y)²),   y = (Π g_s)·x + c_w
/// ```
///
/// where `g_s` are fixed per-stage gains and `c_w = c_shared + 0.1·n_w`
/// is each worker's displaced target (the heterogeneous-shard setup of
/// the elastic quadratic workload, stretched over a real pipeline).  The
/// optimum is realizable, gradients are stage-dependent (each stage's
/// grad carries its downstream gain product, so mis-routed grads are
/// caught), and eval has a closed form: the input term cancels, leaving
/// `½·mean((Σ_s (Π_{j>s} g_j)·w_s − c_shared)²)`.
///
/// Implements the full split backward (input-grad / weight-grad halves),
/// and an optional `compute_passes` cost knob: each forward, input-grad,
/// and weight-grad burns that many busy-loop passes (a fused backward
/// burns twice — it does both halves), so schedule bubbles become
/// measurable wall time without changing any numerics.
#[derive(Clone, Debug)]
pub struct SyntheticPipeline {
    pub stages: usize,
    pub micros: usize,
    /// Activation / per-stage parameter dimension k.
    pub dim: usize,
    pub seed: u64,
    /// Busy-loop passes per op (0 = free, the default).
    pub compute_passes: usize,
}

impl SyntheticPipeline {
    pub fn new(stages: usize, micros: usize, dim: usize, seed: u64) -> Self {
        assert!(stages >= 1 && micros >= 1 && dim >= 1);
        SyntheticPipeline { stages, micros, dim, seed, compute_passes: 0 }
    }

    /// Give every op a measurable cost (see type docs) — for schedule
    /// benchmarks, where the bubble must show up as wall time.
    pub fn with_compute_passes(mut self, passes: usize) -> Self {
        self.compute_passes = passes;
        self
    }

    /// Per-stage gain g_s in [0.85, 1.15] — stage-dependent so gradient
    /// routing errors change the numbers.
    fn gain(&self, s: usize) -> f32 {
        0.85 + 0.3 * (s as f32 + 1.0) / self.stages as f32
    }

    /// Π_{j>s} g_j — the factor a stage's parameter carries to the output.
    fn downstream_gain(&self, s: usize) -> f32 {
        (s + 1..self.stages).map(|j| self.gain(j)).product()
    }

    /// Π over all stages (the input's path to the output).
    fn total_gain(&self) -> f32 {
        (0..self.stages).map(|s| self.gain(s)).product()
    }

    fn shared_target(&self) -> Vec<f32> {
        let mut c = vec![0.0f32; self.dim];
        Pcg32::new(self.seed ^ 0x7a67, 0).fill_normal(&mut c, 0.0, 1.0);
        c
    }

    fn worker_target(&self, worker: usize) -> Vec<f32> {
        let shared = self.shared_target();
        let mut noise = vec![0.0f32; self.dim];
        Pcg32::new(self.seed ^ 0x7a67, 1 + worker as u64)
            .fill_normal(&mut noise, 0.0, 1.0);
        shared
            .iter()
            .zip(&noise)
            .map(|(s, n)| s + 0.1 * n)
            .collect()
    }
}

/// Deterministic busy loop for the compute-cost knob: pure spin, no
/// effect on any training number.
fn burn(passes: usize) {
    let mut acc = 1.0f32;
    for _ in 0..passes {
        for _ in 0..256 {
            acc = std::hint::black_box(acc).mul_add(1.000_000_1, 1.0e-9);
        }
    }
    std::hint::black_box(acc);
}

impl PipelineWorkload for SyntheticPipeline {
    fn stages(&self) -> usize {
        self.stages
    }

    fn micros(&self) -> usize {
        self.micros
    }

    fn stage_numel(&self, _stage: usize) -> usize {
        self.dim
    }

    fn make_stage(&self, worker: usize, stage: usize) -> Result<Box<dyn StageCompute>> {
        if stage >= self.stages {
            return Err(anyhow!("stage {stage} out of range"));
        }
        Ok(Box::new(SyntheticStage {
            cfg: self.clone(),
            stage,
            worker,
            // First and last stage draw the IDENTICAL input stream.
            data_rng: Pcg32::new(self.seed ^ 0xda7a, worker as u64),
            xs: Vec::new(),
            target: self.worker_target(worker),
            stash: HashMap::new(),
            w_stash: HashMap::new(),
        }))
    }

    fn eval(&self, full_params: &[f32]) -> Result<f32> {
        if full_params.len() != self.stages * self.dim {
            return Err(anyhow!(
                "assembled params len {} != {}",
                full_params.len(),
                self.stages * self.dim
            ));
        }
        // Effective output bias Σ_s (Π_{j>s} g_j)·w_s vs the shared
        // target; the input term cancels exactly (see type docs).
        let shared = self.shared_target();
        let mut acc = 0.0f64;
        for i in 0..self.dim {
            let mut eff = 0.0f32;
            for s in 0..self.stages {
                eff += self.downstream_gain(s)
                    * full_params[s * self.dim + i];
            }
            let d = (eff - shared[i]) as f64;
            acc += d * d;
        }
        Ok((0.5 * acc / self.dim as f64) as f32)
    }
}

struct SyntheticStage {
    cfg: SyntheticPipeline,
    stage: usize,
    worker: usize,
    data_rng: Pcg32,
    /// This inner step's microbatch inputs (first & last stages only).
    xs: Vec<Vec<f32>>,
    /// c_w (used by the last stage).
    target: Vec<f32>,
    /// Last stage: a_{M-1} per in-flight micro, for the loss gradient.
    stash: HashMap<usize, Vec<f32>>,
    /// Split backward: activation grad per micro, held between
    /// `backward_input` and `backward_weight`.
    w_stash: HashMap<usize, Vec<f32>>,
}

impl SyntheticStage {
    fn is_first(&self) -> bool {
        self.stage == 0
    }

    fn is_last(&self) -> bool {
        self.stage == self.cfg.stages - 1
    }

    /// Shared core of the fused and split backwards: compute this
    /// stage's activation gradient (== its parameter gradient — the bias
    /// path has unit Jacobian), the upstream message, and the loss.
    fn backward_core(
        &mut self,
        micro: usize,
        grad_in: Option<Vec<f32>>,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>, Option<f32>)> {
        let k = self.cfg.dim as f32;
        let (g_act, loss) = if self.is_last() {
            let a = self
                .stash
                .remove(&micro)
                .ok_or_else(|| anyhow!("no stashed forward for micro {micro}"))?;
            let x = self
                .xs
                .get(micro)
                .ok_or_else(|| anyhow!("micro {micro} not drawn"))?;
            let total = self.cfg.total_gain();
            // y = (Π g)·x + c_w; loss = ½·mean((a − y)²).
            let mut loss = 0.0f64;
            let mut g = vec![0.0f32; self.cfg.dim];
            for i in 0..self.cfg.dim {
                let d = a[i] - (total * x[i] + self.target[i]);
                loss += 0.5 * (d as f64) * (d as f64);
                g[i] = d / k;
            }
            (g, Some((loss / k as f64) as f32))
        } else {
            (
                grad_in.ok_or_else(|| anyhow!("mid/first stage needs grad_in"))?,
                None,
            )
        };
        let upstream = if self.is_first() {
            None
        } else {
            let g = self.cfg.gain(self.stage);
            Some(g_act.iter().map(|v| g * v).collect())
        };
        Ok((g_act, upstream, loss))
    }
}

impl StageCompute for SyntheticStage {
    fn numel(&self) -> usize {
        self.cfg.dim
    }

    fn init(&self) -> Result<Vec<f32>> {
        Ok(vec![0.0; self.cfg.dim])
    }

    fn param_spec(&self) -> Vec<ParamEntry> {
        vec![ParamEntry {
            name: format!("stage{}.w", self.stage),
            shape: vec![self.cfg.dim],
            offset: 0,
        }]
    }

    fn next_step(&mut self) -> Result<()> {
        if self.is_first() || self.is_last() {
            self.xs = (0..self.cfg.micros)
                .map(|_| {
                    let mut x = vec![0.0f32; self.cfg.dim];
                    self.data_rng.fill_normal(&mut x, 0.0, 1.0);
                    x
                })
                .collect();
        }
        Ok(())
    }

    fn reset_data(&mut self, round: usize) -> Result<()> {
        // Pure function of (seed, worker, round): the first and last
        // stage of one cluster re-derive the IDENTICAL stream no matter
        // where churn caught each of them mid-round.
        self.data_rng = Pcg32::new(
            self.cfg.seed
                ^ 0xda7a
                ^ (round as u64).wrapping_mul(0x9e3779b97f4a7c15),
            self.worker as u64,
        );
        self.xs.clear();
        self.stash.clear();
        self.w_stash.clear();
        Ok(())
    }

    fn forward(
        &mut self,
        params: &[f32],
        micro: usize,
        acts_in: Option<Vec<f32>>,
    ) -> Result<Option<Vec<f32>>> {
        burn(self.cfg.compute_passes);
        let input: Vec<f32> = if self.is_first() {
            self.xs
                .get(micro)
                .cloned()
                .ok_or_else(|| anyhow!("micro {micro} not drawn"))?
        } else {
            acts_in.ok_or_else(|| anyhow!("mid/last stage needs acts_in"))?
        };
        let g = self.cfg.gain(self.stage);
        let a: Vec<f32> = input
            .iter()
            .zip(params)
            .map(|(x, w)| g * x + w)
            .collect();
        if self.is_last() {
            self.stash.insert(micro, a);
            Ok(None)
        } else {
            Ok(Some(a))
        }
    }

    fn backward(
        &mut self,
        _params: &[f32],
        micro: usize,
        grad_in: Option<Vec<f32>>,
    ) -> Result<(Vec<f32>, Option<Vec<f32>>, Option<f32>)> {
        // Fused = both halves of the split backward.
        burn(2 * self.cfg.compute_passes);
        let (g_act, upstream, loss) = self.backward_core(micro, grad_in)?;
        // ∂a_s/∂w_s = 1, so the param grad IS the activation grad; the
        // upstream message carries this stage's gain.
        Ok((g_act, upstream, loss))
    }

    fn supports_split_backward(&self) -> bool {
        true
    }

    fn backward_input(
        &mut self,
        _params: &[f32],
        micro: usize,
        grad_in: Option<Vec<f32>>,
    ) -> Result<(Option<Vec<f32>>, Option<f32>)> {
        burn(self.cfg.compute_passes);
        let (g_act, upstream, loss) = self.backward_core(micro, grad_in)?;
        self.w_stash.insert(micro, g_act);
        Ok((upstream, loss))
    }

    fn backward_weight(&mut self, _params: &[f32], micro: usize) -> Result<Vec<f32>> {
        burn(self.cfg.compute_passes);
        self.w_stash
            .remove(&micro)
            .ok_or_else(|| anyhow!("no backward_input for micro {micro}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::faulty::{FaultPlan, FaultyRing};

    fn opts(rounds: usize, overlap: bool) -> PipelineRunOpts {
        PipelineRunOpts {
            rounds,
            local_steps: 8,
            inner_lr: 0.05,
            weight_decay: 0.0,
            outer_lr: 0.7,
            outer_momentum: 0.6,
            overlap,
            error_feedback: false,
            method: Method::None,
            seed: 1234,
            comm_pool_size: 1,
            pipeline_depth: 1,
            schedule: ScheduleKind::OneFOneB,
            virtual_stages: 1,
        }
    }

    #[test]
    fn synthetic_grads_match_closed_form() {
        // Drive the stage computes directly (no threads): the chained
        // backward must reproduce the analytic gradient
        // ∇w_s = (Π_{j>s} g_j)·(a_last − y)/k.
        let wl = SyntheticPipeline::new(3, 2, 5, 42);
        let mut stages: Vec<Box<dyn StageCompute>> =
            (0..3).map(|s| wl.make_stage(0, s).unwrap()).collect();
        let params: Vec<Vec<f32>> = (0..3)
            .map(|s| {
                let mut p = vec![0.0f32; 5];
                Pcg32::new(7, s as u64).fill_normal(&mut p, 0.0, 0.3);
                p
            })
            .collect();
        for st in stages.iter_mut() {
            st.next_step().unwrap();
        }
        for micro in 0..2 {
            let mut acts: Option<Vec<f32>> = None;
            for s in 0..3 {
                acts = stages[s].forward(&params[s], micro, acts).unwrap();
            }
            assert!(acts.is_none(), "last stage keeps its activations");
            let (g2, up2, loss) =
                stages[2].backward(&params[2], micro, None).unwrap();
            let loss = loss.unwrap();
            assert!(loss.is_finite() && loss > 0.0);
            let (g1, up1, l1) =
                stages[1].backward(&params[1], micro, up2).unwrap();
            assert!(l1.is_none());
            let (g0, up0, _) =
                stages[0].backward(&params[0], micro, up1).unwrap();
            assert!(up0.is_none());
            // g2 is the output gradient; downstream gains scale g1, g0.
            for i in 0..5 {
                let want1 = wl.gain(2) * g2[i];
                assert!((g1[i] - want1).abs() < 1e-5, "{} vs {want1}", g1[i]);
                let want0 = wl.gain(1) * wl.gain(2) * g2[i];
                assert!((g0[i] - want0).abs() < 1e-5, "{} vs {want0}", g0[i]);
                assert!(
                    (wl.downstream_gain(0) - wl.gain(1) * wl.gain(2)).abs()
                        < 1e-6
                );
            }
        }
    }

    #[test]
    fn split_backward_matches_fused() {
        // backward_input + backward_weight must reproduce the fused
        // backward bit-for-bit (same upstream grads, same param grads).
        let wl = SyntheticPipeline::new(3, 2, 5, 42);
        for s in 0..3 {
            let mut fused = wl.make_stage(0, s).unwrap();
            let mut split = wl.make_stage(0, s).unwrap();
            assert!(split.supports_split_backward());
            let mut p = vec![0.0f32; 5];
            Pcg32::new(9, s as u64).fill_normal(&mut p, 0.0, 0.3);
            fused.next_step().unwrap();
            split.next_step().unwrap();
            let gi: Option<Vec<f32>> = if s == 2 {
                None
            } else {
                Some((0..5).map(|i| 0.1 * (i as f32 + 1.0)).collect())
            };
            // Feed the last stage a forward so it has a stash.
            if s == 2 {
                let acts = Some(vec![0.5f32; 5]);
                fused.forward(&p, 0, acts.clone()).unwrap();
                split.forward(&p, 0, acts).unwrap();
            }
            let (gp_f, up_f, loss_f) = fused.backward(&p, 0, gi.clone()).unwrap();
            let (up_s, loss_s) = split.backward_input(&p, 0, gi).unwrap();
            let gp_s = split.backward_weight(&p, 0).unwrap();
            assert_eq!(gp_f, gp_s);
            assert_eq!(up_f, up_s);
            assert_eq!(loss_f.map(f32::to_bits), loss_s.map(f32::to_bits));
        }
    }

    #[test]
    fn stage_parallel_converges_and_workers_agree() {
        let wl = SyntheticPipeline::new(3, 4, 16, 99);
        let rings = local_stage_rings(2, 3);
        let out = run_pipeline(&wl, 2, rings, &opts(5, false)).unwrap();
        assert_eq!(out.reports.len(), 2 * 3 * 5);
        assert_eq!(out.final_params.len(), 3 * 16);
        assert!(out.total_wire_bytes > 0);
        // Per-stage wall-time telemetry: one summary per stage, fed by
        // every (worker, round) sample, with sane mean ≤ max ordering.
        let times = out.stage_time_summary();
        assert_eq!(times.len(), 3);
        for t in &times {
            assert_eq!(t.samples, 2 * 5);
            assert!(t.mean_step_secs >= 0.0);
            assert!(t.max_step_secs >= t.mean_step_secs);
        }
        // The run report JSON round-trips through the parser.
        let j = out.to_json();
        let parsed =
            crate::util::json::Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(
            parsed.path("stage_times").unwrap().as_arr().unwrap().len(),
            3
        );
        assert!(parsed.path("final_eval").unwrap().as_f64().is_some());
        let curve = out.mean_loss_per_round();
        assert_eq!(curve.len(), 5);
        let first = curve.first().unwrap().1;
        assert!(
            out.final_eval < first * 0.5,
            "final {} vs round-1 {first}",
            out.final_eval
        );
    }

    #[test]
    fn all_schedules_agree_bit_for_bit() {
        // The same 8-model-stage workload run under every schedule —
        // including interleaved regrouped as 4 executors × 2 chunks and
        // 2 executors × 4 chunks — must land on IDENTICAL final params:
        // same per-(chunk, micro) gradient algebra, same per-model-stage
        // ring reduction, same elementwise optimizers.
        let wl = SyntheticPipeline::new(8, 8, 8, 77);
        let run = |kind: ScheduleKind, v: usize| {
            let mut o = opts(3, false);
            o.schedule = kind;
            o.virtual_stages = v;
            run_pipeline(&wl, 2, local_stage_rings(2, 8), &o).unwrap()
        };
        let base = run(ScheduleKind::OneFOneB, 1);
        assert!(base.final_eval.is_finite());
        for (kind, v) in [
            (ScheduleKind::GPipe, 1),
            (ScheduleKind::ZeroBubble, 1),
            (ScheduleKind::Interleaved, 1),
            (ScheduleKind::Interleaved, 2),
            (ScheduleKind::Interleaved, 4),
        ] {
            let out = run(kind, v);
            assert_eq!(
                base.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                out.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "{} v={v} diverged from 1f1b",
                kind.name()
            );
        }
    }

    #[test]
    fn zero_bubble_runs_with_fused_fallback() {
        // A compute WITHOUT split backward still runs zero-bubble
        // streams (fused at B, collect at W) and matches its own 1f1b
        // result bit-for-bit.
        struct Fused(SyntheticPipeline);
        struct FusedStage(Box<dyn StageCompute>);
        impl StageCompute for FusedStage {
            fn numel(&self) -> usize {
                self.0.numel()
            }
            fn init(&self) -> Result<Vec<f32>> {
                self.0.init()
            }
            fn param_spec(&self) -> Vec<ParamEntry> {
                self.0.param_spec()
            }
            fn next_step(&mut self) -> Result<()> {
                self.0.next_step()
            }
            fn reset_data(&mut self, round: usize) -> Result<()> {
                self.0.reset_data(round)
            }
            fn forward(
                &mut self,
                params: &[f32],
                micro: usize,
                acts_in: Option<Vec<f32>>,
            ) -> Result<Option<Vec<f32>>> {
                self.0.forward(params, micro, acts_in)
            }
            fn backward(
                &mut self,
                params: &[f32],
                micro: usize,
                grad_in: Option<Vec<f32>>,
            ) -> Result<(Vec<f32>, Option<Vec<f32>>, Option<f32>)> {
                self.0.backward(params, micro, grad_in)
            }
            // supports_split_backward stays false (the default).
        }
        impl PipelineWorkload for Fused {
            fn stages(&self) -> usize {
                self.0.stages()
            }
            fn micros(&self) -> usize {
                self.0.micros()
            }
            fn stage_numel(&self, s: usize) -> usize {
                self.0.stage_numel(s)
            }
            fn make_stage(&self, w: usize, s: usize) -> Result<Box<dyn StageCompute>> {
                Ok(Box::new(FusedStage(self.0.make_stage(w, s)?)))
            }
            fn eval(&self, p: &[f32]) -> Result<f32> {
                self.0.eval(p)
            }
        }
        let wl = Fused(SyntheticPipeline::new(3, 4, 8, 13));
        let mut o = opts(3, false);
        o.schedule = ScheduleKind::ZeroBubble;
        let zb = run_pipeline(&wl, 2, local_stage_rings(2, 3), &o).unwrap();
        let base =
            run_pipeline(&wl, 2, local_stage_rings(2, 3), &opts(3, false))
                .unwrap();
        assert_eq!(
            base.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            zb.final_params.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        );
    }

    #[test]
    fn overlap_defers_round_one_and_still_converges() {
        let wl = SyntheticPipeline::new(2, 3, 16, 7);
        let rings = local_stage_rings(2, 2);
        // One-step-delayed outer updates at high gain oscillate on this
        // fast-converging chain (each H-step block moves a large fraction
        // toward the optimum, unlike a real transformer round), so the
        // overlap tests run the outer optimizer gently.
        let mut o = opts(6, true);
        o.outer_lr = 0.3;
        o.outer_momentum = 0.3;
        let out = run_pipeline(&wl, 2, rings, &o).unwrap();
        // Round 1: nothing in flight yet — zero wire on every stage.
        assert!(out
            .reports
            .iter()
            .filter(|r| r.round == 1)
            .all(|r| r.wire_bytes == 0));
        assert!(out
            .reports
            .iter()
            .filter(|r| r.round == 2)
            .all(|r| r.wire_bytes > 0));
        let first = out.mean_loss_per_round().first().unwrap().1;
        assert!(out.final_eval < first * 0.5, "{}", out.final_eval);
    }

    #[test]
    fn interleaved_overlap_and_zero_bubble_overlap_converge() {
        let wl = SyntheticPipeline::new(4, 4, 16, 7);
        for (kind, v) in
            [(ScheduleKind::Interleaved, 2), (ScheduleKind::ZeroBubble, 1)]
        {
            let mut o = opts(6, true);
            o.outer_lr = 0.3;
            o.outer_momentum = 0.3;
            o.schedule = kind;
            o.virtual_stages = v;
            let out =
                run_pipeline(&wl, 2, local_stage_rings(2, 4), &o).unwrap();
            assert!(out.final_eval.is_finite());
            assert!(out
                .reports
                .iter()
                .filter(|r| r.round == 2)
                .all(|r| r.wire_bytes > 0));
        }
    }

    #[test]
    fn single_stage_single_micro_edge_case_runs() {
        let wl = SyntheticPipeline::new(1, 1, 8, 3);
        let rings = local_stage_rings(2, 1);
        let out = run_pipeline(&wl, 2, rings, &opts(4, false)).unwrap();
        assert!(out.final_eval.is_finite());
        assert_eq!(out.final_params.len(), 8);
    }

    #[test]
    fn composes_with_fault_injecting_transport() {
        // Wrap every per-stage ring member in the seeded delay injector:
        // the executor must tolerate arbitrary collective timing.
        let wl = SyntheticPipeline::new(2, 2, 8, 11);
        let plan = FaultPlan {
            seed: 5,
            delay_prob: 0.5,
            max_delay_ms: 2,
            kill_round: 0,
            break_round: 0,
            straggler_ms: 0,
            exit_on_kill: false,
        };
        let rings: Vec<Vec<Box<dyn RingTransport>>> = local_stage_rings(2, 2)
            .into_iter()
            .map(|worker| {
                worker
                    .into_iter()
                    .map(|m| {
                        Box::new(FaultyRing::new(m, plan.clone()))
                            as Box<dyn RingTransport>
                    })
                    .collect()
            })
            .collect();
        let out = run_pipeline(&wl, 2, rings, &opts(3, false)).unwrap();
        assert!(out.final_eval.is_finite());
        assert!(out.total_wire_bytes > 0);
    }

    #[test]
    fn quantized_compression_runs_per_stage() {
        let wl = SyntheticPipeline::new(2, 2, 16, 21);
        let rings = local_stage_rings(2, 2);
        let mut o = opts(4, false);
        o.method = Method::Quant { q_bits: 8 };
        o.error_feedback = true;
        let out = run_pipeline(&wl, 2, rings, &o).unwrap();
        let first = out.mean_loss_per_round().first().unwrap().1;
        assert!(out.final_eval < first, "{} vs {first}", out.final_eval);
        // int8 wire: ~1 byte/elem instead of 4.
        let per_round: u64 = out
            .reports
            .iter()
            .filter(|r| r.round == 1 && r.worker == 0)
            .map(|r| r.wire_bytes)
            .sum();
        assert!(per_round < 2 * 2 * 16, "wire {per_round}");
    }

    #[test]
    fn quantized_compression_runs_interleaved() {
        // Compression composes with virtual stages (the chunked ring
        // reduces compressed payloads whole over its first sub-ring).
        let wl = SyntheticPipeline::new(4, 4, 16, 21);
        let mut o = opts(4, false);
        o.method = Method::Quant { q_bits: 8 };
        o.error_feedback = true;
        o.schedule = ScheduleKind::Interleaved;
        o.virtual_stages = 2;
        let out = run_pipeline(&wl, 2, local_stage_rings(2, 4), &o).unwrap();
        let first = out.mean_loss_per_round().first().unwrap().1;
        assert!(out.final_eval < first, "{} vs {first}", out.final_eval);
        assert!(out.total_wire_bytes > 0);
    }

    #[test]
    fn mpsc_links_route_acts_and_grads_by_chunk_and_micro() {
        let mut links = mpsc_stage_links(2);
        let mut l1 = links.pop().unwrap();
        let mut l0 = links.pop().unwrap();
        assert!(!l0.has_upstream() && l0.has_downstream());
        assert!(l1.has_upstream() && !l1.has_downstream());
        l0.send_acts(0, 0, vec![1.0]).unwrap();
        assert_eq!(l1.recv_acts().unwrap(), (0, 0, vec![1.0]));
        l1.send_grads(1, 3, vec![2.0]).unwrap();
        assert_eq!(l0.recv_grads().unwrap(), (1, 3, vec![2.0]));
        // Endpoint misuse is an error, not a hang.
        assert!(l0.recv_acts().is_err());
        assert!(l1.send_acts(0, 0, vec![0.0]).is_err());
    }

    #[test]
    fn ring_links_wrap_and_self_loop() {
        let mut links = mpsc_stage_links_ring(2);
        let mut l1 = links.pop().unwrap();
        let mut l0 = links.pop().unwrap();
        assert!(l0.has_upstream() && l0.has_downstream());
        assert!(l1.has_upstream() && l1.has_downstream());
        // Wrap: stage 1's acts go to stage 0 (next chunk).
        l1.send_acts(1, 0, vec![4.0]).unwrap();
        assert_eq!(l0.recv_acts().unwrap(), (1, 0, vec![4.0]));
        // And stage 0's grads go back to stage 1.
        l0.send_grads(0, 2, vec![5.0]).unwrap();
        assert_eq!(l1.recv_grads().unwrap(), (0, 2, vec![5.0]));
        // Single executor: the link loops to itself.
        let mut solo = mpsc_stage_links_ring(1);
        let mut l = solo.pop().unwrap();
        l.send_acts(1, 0, vec![6.0]).unwrap();
        assert_eq!(l.recv_acts().unwrap(), (1, 0, vec![6.0]));
    }

    #[test]
    fn chunked_ring_matches_separate_rings() {
        // Reducing [a | b] through a ChunkedRing must equal reducing a
        // and b over the separate rings — bitwise.
        let dp = 3;
        let (na, nb) = (7usize, 5usize);
        let mk = |w: usize, salt: u64, n: usize| {
            let mut v = vec![0.0f32; n];
            Pcg32::new(salt, w as u64).fill_normal(&mut v, 0.0, 1.0);
            v
        };
        // Separate reference.
        let mut ra = build_ring(dp);
        let mut rb = build_ring(dp);
        let mut want: Vec<Vec<f32>> = Vec::new();
        let hs: Vec<_> = (0..dp)
            .map(|w| {
                let mut ma = ra.remove(0);
                let mut mb = rb.remove(0);
                std::thread::spawn(move || {
                    let mut a = mk(w, 3, na);
                    let mut b = mk(w, 4, nb);
                    ma.allreduce_sum(&mut a).unwrap();
                    mb.allreduce_sum(&mut b).unwrap();
                    (a, b)
                })
            })
            .collect();
        for h in hs {
            let (a, b) = h.join().unwrap();
            let mut full = a;
            full.extend_from_slice(&b);
            want.push(full);
        }
        // Chunked.
        let mut r1 = build_ring(dp);
        let mut r2 = build_ring(dp);
        let hs: Vec<_> = (0..dp)
            .map(|w| {
                let m1 = Box::new(r1.remove(0)) as Box<dyn RingTransport>;
                let m2 = Box::new(r2.remove(0)) as Box<dyn RingTransport>;
                std::thread::spawn(move || {
                    let mut ring =
                        ChunkedRing::new(vec![m1, m2], vec![na, nb]).unwrap();
                    let mut full = mk(w, 3, na);
                    full.extend_from_slice(&mk(w, 4, nb));
                    ring.allreduce_sum(&mut full).unwrap();
                    assert!(ring.meter().total() > 0);
                    full
                })
            })
            .collect();
        for (w, h) in hs.into_iter().enumerate() {
            let got = h.join().unwrap();
            assert_eq!(
                want[w].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            );
        }
    }

    #[test]
    fn rejects_bad_shapes_and_methods() {
        let wl = SyntheticPipeline::new(2, 2, 4, 1);
        assert!(run_pipeline(&wl, 2, local_stage_rings(2, 1), &opts(1, false))
            .is_err());
        let mut o = opts(1, false);
        o.method = Method::TopK { ratio: 0.1, q_bits: 4 };
        assert!(run_pipeline(&wl, 2, local_stage_rings(2, 2), &o).is_err());
        // virtual_stages must divide the model stage count, and only the
        // interleaved schedule accepts v > 1.
        let mut o = opts(1, false);
        o.schedule = ScheduleKind::Interleaved;
        o.virtual_stages = 3;
        assert!(run_pipeline(&wl, 2, local_stage_rings(2, 2), &o).is_err());
        let mut o = opts(1, false);
        o.virtual_stages = 2;
        assert!(run_pipeline(&wl, 2, local_stage_rings(2, 2), &o).is_err());
    }
}
