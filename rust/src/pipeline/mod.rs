//! Pipeline parallelism (paper §2.2): stage partitioning, microbatch
//! schedules, and the stage-parallel executor ([`exec`]).  The schedule is
//! an abstract per-stage op-stream that three consumers share — the
//! schedule validator and the DES throughput simulator interpret it
//! through [`execute_streams`] (the single dependency oracle), and the
//! real executor's stage threads run their streams in order with blocking
//! channels realizing the same dependencies structurally — one source of
//! truth for the dependency structure and therefore for bubble fractions.

pub mod exec;

/// One scheduled cell: stage `stage` runs a forward or backward for
/// microbatch `micro`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cell {
    pub stage: usize,
    pub micro: usize,
    pub is_forward: bool,
}

/// GPipe fill-drain: all forwards (in microbatch-major order), then all
/// backwards (reverse).  Bubble fraction = (M−1)/(M−1+U) per phase.
pub fn gpipe_schedule(stages: usize, micros: usize) -> Vec<Cell> {
    let mut cells = Vec::with_capacity(2 * stages * micros);
    for m in 0..micros {
        for s in 0..stages {
            cells.push(Cell { stage: s, micro: m, is_forward: true });
        }
    }
    for m in (0..micros).rev() {
        for s in (0..stages).rev() {
            cells.push(Cell { stage: s, micro: m, is_forward: false });
        }
    }
    cells
}

/// 1F1B (PipeDream-flush): warm-up forwards, steady-state alternation,
/// drain backwards.  Same bubble as GPipe but bounded activation memory
/// (≤ stages in flight instead of ≤ micros).
pub fn one_f_one_b_schedule(stages: usize, micros: usize) -> Vec<Vec<Cell>> {
    // Per-stage op streams (each stage executes its own stream in order).
    let mut streams = vec![Vec::new(); stages];
    for (s, stream) in streams.iter_mut().enumerate() {
        let warmup = (stages - 1 - s).min(micros);
        let mut next_f = 0usize;
        let mut next_b = 0usize;
        for _ in 0..warmup {
            stream.push(Cell { stage: s, micro: next_f, is_forward: true });
            next_f += 1;
        }
        while next_b < micros {
            if next_f < micros {
                stream.push(Cell { stage: s, micro: next_f, is_forward: true });
                next_f += 1;
            }
            stream.push(Cell { stage: s, micro: next_b, is_forward: false });
            next_b += 1;
        }
    }
    streams
}

/// Per-(stage, micro) completion values from an interpretation of
/// per-stage streams (see [`execute_streams`]).
#[derive(Clone, Debug)]
pub struct ScheduleTrace<T> {
    pub fwd: Vec<Vec<T>>,
    pub bwd: Vec<Vec<T>>,
}

/// Interpret per-stage streams against the pipeline dependency rules,
/// calling `f(cell, fwd_dep, bwd_dep)` exactly once per cell when its
/// dependencies have completed:
///
/// * forward at stage s: `fwd_dep` = completion of the forward of
///   (s−1, micro) — `None` at stage 0; `bwd_dep` is `None`;
/// * backward at stage s: `fwd_dep` = completion of this stage's own
///   forward of (s, micro); `bwd_dep` = completion of the backward of
///   (s+1, micro) — `None` at the last stage.
///
/// `f` returns the cell's own completion value (`()` for pure
/// validation, a finish *time* for the DES).  Errors on deadlock or
/// missing ops.  This is the single dependency oracle: the schedule
/// validator and the DES simulator call it directly, and the real
/// stage-parallel executor ([`exec`]) realizes the identical rules
/// structurally (per-stage in-order streams + blocking channels).
pub fn execute_streams<T: Clone, F>(
    streams: &[Vec<Cell>],
    micros: usize,
    mut f: F,
) -> Result<ScheduleTrace<T>, String>
where
    F: FnMut(Cell, Option<&T>, Option<&T>) -> T,
{
    let stages = streams.len();
    let mut fwd: Vec<Vec<Option<T>>> = vec![vec![None; micros]; stages];
    let mut bwd: Vec<Vec<Option<T>>> = vec![vec![None; micros]; stages];
    let mut idx = vec![0usize; stages];
    let total: usize = streams.iter().map(|s| s.len()).sum();
    let mut executed = 0usize;
    while executed < total {
        let mut progressed = false;
        for s in 0..stages {
            while idx[s] < streams[s].len() {
                let c = streams[s][idx[s]];
                if c.stage != s {
                    return Err(format!(
                        "stream {s} carries a cell for stage {}",
                        c.stage
                    ));
                }
                if c.micro >= micros {
                    return Err(format!(
                        "cell micro {} out of range (micros {micros})",
                        c.micro
                    ));
                }
                // Dependency completion values (None = not ready yet).
                let deps: Option<(Option<T>, Option<T>)> = if c.is_forward {
                    if s == 0 {
                        Some((None, None))
                    } else {
                        fwd[s - 1][c.micro].clone().map(|t| (Some(t), None))
                    }
                } else {
                    match fwd[s][c.micro].clone() {
                        None => None,
                        Some(own) => {
                            if s == stages - 1 {
                                Some((Some(own), None))
                            } else {
                                bwd[s + 1][c.micro]
                                    .clone()
                                    .map(|d| (Some(own), Some(d)))
                            }
                        }
                    }
                };
                let Some((fdep, bdep)) = deps else { break };
                let v = f(c, fdep.as_ref(), bdep.as_ref());
                if c.is_forward {
                    fwd[s][c.micro] = Some(v);
                } else {
                    bwd[s][c.micro] = Some(v);
                }
                idx[s] += 1;
                executed += 1;
                progressed = true;
            }
        }
        if !progressed {
            return Err(format!("schedule deadlock at {executed}/{total} ops"));
        }
    }
    let unwrap_all = |table: Vec<Vec<Option<T>>>, what: &str| {
        let mut out = Vec::with_capacity(table.len());
        for (s, row) in table.into_iter().enumerate() {
            let mut r = Vec::with_capacity(row.len());
            for (m, v) in row.into_iter().enumerate() {
                match v {
                    Some(v) => r.push(v),
                    None => {
                        return Err(format!(
                            "missing {what} op for stage {s} micro {m}"
                        ))
                    }
                }
            }
            out.push(r);
        }
        Ok(out)
    };
    Ok(ScheduleTrace {
        fwd: unwrap_all(fwd, "forward")?,
        bwd: unwrap_all(bwd, "backward")?,
    })
}

/// Validity check used by executors and property tests: within each
/// stage ops are ordered, forward of (s, m) precedes forward of (s+1, m),
/// backward of (s, m) precedes backward of (s−1, m), and the backward of
/// the last stage follows its forward.
pub fn validate_schedule(streams: &[Vec<Cell>], micros: usize) -> Result<(), String> {
    execute_streams(streams, micros, |_c, _f, _b| ()).map(|_| ())
}

/// Partition L layers over M stages (equal split required, as in aot.py).
pub fn layers_per_stage(n_layers: usize, stages: usize) -> Result<usize, String> {
    if stages == 0 || n_layers % stages != 0 {
        return Err(format!("{n_layers} layers not divisible by {stages} stages"));
    }
    Ok(n_layers / stages)
}

/// Ideal-pipeline bubble fraction for a fill-drain schedule.
pub fn bubble_fraction(stages: usize, micros: usize) -> f64 {
    let m = stages as f64;
    let u = micros as f64;
    (m - 1.0) / (m - 1.0 + u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::props;

    #[test]
    fn gpipe_has_all_cells_in_dependency_order() {
        let cells = gpipe_schedule(4, 3);
        assert_eq!(cells.len(), 2 * 4 * 3);
        // Split into per-stage streams and validate.
        let mut streams = vec![Vec::new(); 4];
        for c in cells {
            streams[c.stage].push(c);
        }
        validate_schedule(&streams, 3).unwrap();
    }

    #[test]
    fn one_f_one_b_is_valid_property() {
        props(61).runs(40).check(|g| {
            let stages = g.usize_in(1, 8);
            let micros = g.usize_in(1, 12);
            let streams = one_f_one_b_schedule(stages, micros);
            validate_schedule(&streams, micros).map_err(|e| e)
        });
    }

    #[test]
    fn one_f_one_b_bounds_in_flight_activations() {
        let stages = 4;
        let micros = 12;
        let streams = one_f_one_b_schedule(stages, micros);
        for (s, stream) in streams.iter().enumerate() {
            let mut live: i64 = 0;
            let mut peak: i64 = 0;
            for c in stream {
                live += if c.is_forward { 1 } else { -1 };
                peak = peak.max(live);
            }
            let bound = (stages - s) as i64;
            assert!(peak <= bound, "stage {s}: peak {peak} > {bound}");
        }
    }

    #[test]
    fn stage0_of_1f1b_interleaves() {
        let streams = one_f_one_b_schedule(3, 6);
        let s0: Vec<bool> = streams[0].iter().map(|c| c.is_forward).collect();
        // warm-up of 2 forwards, then alternating, then drain.
        assert_eq!(s0[0..2], [true, true]);
        assert!(s0.windows(2).any(|w| w == [true, false]));
        assert_eq!(s0.last(), Some(&false));
    }

    #[test]
    fn bubble_shrinks_with_more_microbatches() {
        assert!(bubble_fraction(8, 1) > bubble_fraction(8, 32));
        assert!((bubble_fraction(8, 32) - 7.0 / 39.0).abs() < 1e-12);
        assert_eq!(bubble_fraction(1, 4), 0.0);
    }

    #[test]
    fn layer_partitioning() {
        assert_eq!(layers_per_stage(12, 4).unwrap(), 3);
        assert!(layers_per_stage(10, 4).is_err());
        assert!(layers_per_stage(4, 0).is_err());
    }

    #[test]
    fn execute_streams_yields_dependency_consistent_trace() {
        let streams = one_f_one_b_schedule(3, 4);
        let mut clock = 0usize;
        let trace = execute_streams(&streams, 4, |_c, f, b| {
            clock += 1;
            assert!(f.map_or(true, |&x| x < clock));
            assert!(b.map_or(true, |&x| x < clock));
            clock
        })
        .unwrap();
        for s in 0..3 {
            for m in 0..4 {
                assert!(trace.fwd[s][m] < trace.bwd[s][m]);
                if s > 0 {
                    assert!(trace.fwd[s - 1][m] < trace.fwd[s][m]);
                }
                if s < 2 {
                    assert!(trace.bwd[s + 1][m] < trace.bwd[s][m]);
                }
            }
        }
    }

    #[test]
    fn deadlock_detection_catches_bad_schedule() {
        // Backward before its forward on the last stage.
        let streams = vec![vec![
            Cell { stage: 0, micro: 0, is_forward: false },
            Cell { stage: 0, micro: 0, is_forward: true },
        ]];
        assert!(validate_schedule(&streams, 1).is_err());
    }
}
