//! Pipeline parallelism (paper §2.2): stage partitioning, microbatch
//! schedules, and the stage-parallel executor ([`exec`]).  The schedule is
//! an abstract per-executor op-stream that three consumers share — the
//! schedule validator and the DES throughput simulator interpret it
//! through [`execute_streams`] (the single dependency oracle), and the
//! real executor's stage threads run their streams in order with blocking
//! channels realizing the same dependencies structurally — one source of
//! truth for the dependency structure and therefore for bubble fractions.
//!
//! Four schedules share the [`Cell`] stream format (pick one with
//! [`ScheduleKind`]):
//!
//! * [`gpipe_schedule`] — fill-drain; bubble (S−1)/(M+S−1).
//! * [`one_f_one_b_schedule`] — PipeDream-flush 1F1B; same bubble, bounded
//!   activation memory.
//! * [`interleaved_1f1b_schedule`] — Megatron-style virtual stages: each
//!   executor owns `v` model chunks, shrinking the bubble ~1/v at the cost
//!   of a wrap-around activation link (executor S−1 → 0).
//! * [`zero_bubble_schedule`] — ZB-H1-style: the backward is split into an
//!   input-grad op `B` kept on the critical path and a weight-grad op `W`
//!   back-filled into the drain bubbles, driving the bubble toward zero
//!   when F ≈ B ≈ W.

pub mod exec;

/// What a scheduled cell computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Forward one microbatch through this model chunk.
    F,
    /// Backward: with a matching [`OpKind::W`] cell in the stream this is
    /// the *input-grad* half (activation gradients only — the part the
    /// upstream stage is waiting for); without one it is the classic
    /// fused backward (input + weight grads in one op).
    B,
    /// Weight-grad half of a split backward — off the critical path, so
    /// schedulers back-fill it into bubbles.  Must follow its own `B`.
    W,
}

/// One scheduled cell: executor `stage` runs `op` for microbatch `micro`
/// of virtual-stage chunk `chunk` (chunk 0 for non-interleaved
/// schedules).  The model stage it touches is `chunk·S + stage` — chunk 1
/// of every executor sits *after* chunk 0 of all executors, Megatron
/// virtual-pipeline style.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Cell {
    pub stage: usize,
    pub chunk: usize,
    pub micro: usize,
    pub op: OpKind,
}

impl Cell {
    pub fn f(stage: usize, chunk: usize, micro: usize) -> Cell {
        Cell { stage, chunk, micro, op: OpKind::F }
    }

    pub fn b(stage: usize, chunk: usize, micro: usize) -> Cell {
        Cell { stage, chunk, micro, op: OpKind::B }
    }

    pub fn w(stage: usize, chunk: usize, micro: usize) -> Cell {
        Cell { stage, chunk, micro, op: OpKind::W }
    }

    pub fn is_forward(&self) -> bool {
        self.op == OpKind::F
    }

    /// Global model-stage index of this cell on an S-executor pipeline.
    pub fn model_stage(&self, stages: usize) -> usize {
        self.chunk * stages + self.stage
    }
}

/// The schedule axis: which microbatch schedule the executor (and the
/// DES) runs.  Parsed from `[parallel] schedule` / `--schedule`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleKind {
    GPipe,
    OneFOneB,
    Interleaved,
    ZeroBubble,
}

impl ScheduleKind {
    pub fn parse(s: &str) -> Result<ScheduleKind, String> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "gpipe" => ScheduleKind::GPipe,
            "1f1b" | "one-f-one-b" | "pipedream" => ScheduleKind::OneFOneB,
            "interleaved" | "virtual" => ScheduleKind::Interleaved,
            "zero-bubble" | "zerobubble" | "zb" | "zb-h1" => {
                ScheduleKind::ZeroBubble
            }
            other => {
                return Err(format!(
                    "unknown schedule '{other}' \
                     (gpipe | 1f1b | interleaved | zero-bubble)"
                ))
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScheduleKind::GPipe => "gpipe",
            ScheduleKind::OneFOneB => "1f1b",
            ScheduleKind::Interleaved => "interleaved",
            ScheduleKind::ZeroBubble => "zero-bubble",
        }
    }

    pub fn all() -> [ScheduleKind; 4] {
        [
            ScheduleKind::GPipe,
            ScheduleKind::OneFOneB,
            ScheduleKind::Interleaved,
            ScheduleKind::ZeroBubble,
        ]
    }

    /// Per-executor op streams for `executors` executors running
    /// `virtual_stages` chunks each over `micros` microbatches.  Only the
    /// interleaved schedule accepts `virtual_stages > 1`.
    pub fn streams(
        &self,
        executors: usize,
        virtual_stages: usize,
        micros: usize,
    ) -> Result<Vec<Vec<Cell>>, String> {
        if executors == 0 || micros == 0 || virtual_stages == 0 {
            return Err("executors, micros, virtual_stages must be >= 1".into());
        }
        if virtual_stages > 1 && *self != ScheduleKind::Interleaved {
            return Err(format!(
                "schedule '{}' does not support virtual_stages > 1 \
                 (only 'interleaved' does)",
                self.name()
            ));
        }
        Ok(match self {
            ScheduleKind::GPipe => gpipe_schedule(executors, micros),
            ScheduleKind::OneFOneB => one_f_one_b_schedule(executors, micros),
            ScheduleKind::Interleaved => {
                interleaved_1f1b_schedule(executors, virtual_stages, micros)?
            }
            ScheduleKind::ZeroBubble => zero_bubble_schedule(executors, micros),
        })
    }

    /// Theoretical bubble fraction of this schedule at uniform per-cell
    /// cost (forward = input-grad = weight-grad): the fill-drain family
    /// pays (S−1)/(M+S−1), interleaving divides the fill/drain ramp by v,
    /// and the ZB-H1 back-fill drives it to ~0.
    pub fn ideal_bubble_fraction(
        &self,
        executors: usize,
        virtual_stages: usize,
        micros: usize,
    ) -> f64 {
        let s = executors as f64;
        let m = micros as f64;
        let v = virtual_stages.max(1) as f64;
        match self {
            ScheduleKind::GPipe | ScheduleKind::OneFOneB => {
                (s - 1.0) / (m + s - 1.0)
            }
            ScheduleKind::Interleaved => ((s - 1.0) / v) / (m + s - 1.0),
            ScheduleKind::ZeroBubble => 0.0,
        }
    }
}

/// GPipe fill-drain: all forwards (in microbatch-major order), then all
/// backwards (reverse).  Bubble fraction = (S−1)/(M+S−1).
pub fn gpipe_schedule(stages: usize, micros: usize) -> Vec<Vec<Cell>> {
    let mut streams = vec![Vec::with_capacity(2 * micros); stages];
    for m in 0..micros {
        for (s, stream) in streams.iter_mut().enumerate() {
            stream.push(Cell::f(s, 0, m));
        }
    }
    for m in (0..micros).rev() {
        for (s, stream) in streams.iter_mut().enumerate() {
            stream.push(Cell::b(s, 0, m));
        }
    }
    streams
}

/// 1F1B (PipeDream-flush): warm-up forwards, steady-state alternation,
/// drain backwards.  Same bubble as GPipe but bounded activation memory
/// (≤ stages in flight instead of ≤ micros).
pub fn one_f_one_b_schedule(stages: usize, micros: usize) -> Vec<Vec<Cell>> {
    // Per-stage op streams (each stage executes its own stream in order).
    let mut streams = vec![Vec::new(); stages];
    for (s, stream) in streams.iter_mut().enumerate() {
        let warmup = (stages - 1 - s).min(micros);
        let mut next_f = 0usize;
        let mut next_b = 0usize;
        for _ in 0..warmup {
            stream.push(Cell::f(s, 0, next_f));
            next_f += 1;
        }
        while next_b < micros {
            if next_f < micros {
                stream.push(Cell::f(s, 0, next_f));
                next_f += 1;
            }
            stream.push(Cell::b(s, 0, next_b));
            next_b += 1;
        }
    }
    streams
}

/// Megatron-style interleaved virtual-stage 1F1B: each executor owns
/// `virtual_per_stage` model chunks (model stage `c·S + s` for chunk c on
/// executor s), so the fill/drain ramp crosses each executor v times with
/// 1/v of the work — bubble ~((S−1)/v)/(M+S−1).  Activations wrap from
/// executor S−1 back to executor 0 between consecutive chunks.
/// Requires `micros % stages == 0` when `virtual_per_stage > 1` (the
/// Megatron microbatch-group constraint).
pub fn interleaved_1f1b_schedule(
    stages: usize,
    virtual_per_stage: usize,
    micros: usize,
) -> Result<Vec<Vec<Cell>>, String> {
    let v = virtual_per_stage;
    if v == 0 {
        return Err("virtual_per_stage must be >= 1".into());
    }
    if v > 1 && micros % stages != 0 {
        return Err(format!(
            "interleaved schedule with {v} virtual stages needs \
             micros ({micros}) divisible by stages ({stages})"
        ));
    }
    // Iteration i of the forward (resp. backward) pass on any executor
    // maps to chunk (i mod S·v)/S — reversed for backwards — and
    // microbatch (i div S·v)·S + (i mod S): microbatch groups of S sweep
    // each chunk in turn (Megatron's get_model_chunk_id enumeration).
    let group = stages * v;
    let total = micros * v;
    let f_chunk = |i: usize| (i % group) / stages;
    let b_chunk = |i: usize| v - 1 - (i % group) / stages;
    let micro_of = |i: usize| (i / group) * stages + i % stages;
    let mut streams = vec![Vec::with_capacity(2 * total); stages];
    for (s, stream) in streams.iter_mut().enumerate() {
        let warmup = (2 * (stages - 1 - s) + (v - 1) * stages).min(total);
        for i in 0..warmup {
            stream.push(Cell::f(s, f_chunk(i), micro_of(i)));
        }
        for j in 0..total - warmup {
            let i = warmup + j;
            stream.push(Cell::f(s, f_chunk(i), micro_of(i)));
            stream.push(Cell::b(s, b_chunk(j), micro_of(j)));
        }
        for j in total - warmup..total {
            stream.push(Cell::b(s, b_chunk(j), micro_of(j)));
        }
    }
    Ok(streams)
}

/// ZB-H1-style zero-bubble schedule: the backward is split into the
/// input-grad op `B` (critical path — the upstream stage waits on it) and
/// the weight-grad op `W` (no one waits on it), with `W`s back-filled
/// into the drain-phase bubbles.  The warm-up runs 2·(S−1−s) forwards —
/// deep enough that at uniform cost (F = B = W) the first input-grad
/// arrives exactly when the warm-up ends, leaving (near) zero idle.
/// Steady state pairs F with B; the drain alternates B with back-filled
/// Ws and flushes the W backlog at the end.  Trades activation memory
/// (up to 2(S−1)+1 microbatches in flight on stage 0, vs S for 1F1B) for
/// the bubble, like the ZB-H2 end of the zero-bubble family.
pub fn zero_bubble_schedule(stages: usize, micros: usize) -> Vec<Vec<Cell>> {
    let mut streams = vec![Vec::with_capacity(3 * micros); stages];
    for (s, stream) in streams.iter_mut().enumerate() {
        let warmup = (2 * (stages - 1 - s)).min(micros);
        let mut next_f = 0usize;
        let mut next_b = 0usize;
        let mut next_w = 0usize;
        for _ in 0..warmup {
            stream.push(Cell::f(s, 0, next_f));
            next_f += 1;
        }
        // Steady state: strict 1F1B pairs; weight grads pile up.
        while next_f < micros {
            stream.push(Cell::f(s, 0, next_f));
            next_f += 1;
            stream.push(Cell::b(s, 0, next_b));
            next_b += 1;
        }
        // Drain: input grads stay on the critical path, weight grads
        // back-fill the wait for the next downstream grad.
        while next_b < micros {
            stream.push(Cell::b(s, 0, next_b));
            next_b += 1;
            if next_w < next_b {
                stream.push(Cell::w(s, 0, next_w));
                next_w += 1;
            }
        }
        while next_w < micros {
            stream.push(Cell::w(s, 0, next_w));
            next_w += 1;
        }
    }
    streams
}

/// Per-(model stage, micro) completion values from an interpretation of
/// per-executor streams (see [`execute_streams`]).  Tables are indexed
/// `[chunk·S + stage][micro]`; `wgrad` entries are `None` where the
/// schedule had no weight-grad cell (only zero-bubble schedules emit
/// them).
#[derive(Clone, Debug)]
pub struct ScheduleTrace<T> {
    pub fwd: Vec<Vec<T>>,
    pub bwd: Vec<Vec<T>>,
    pub wgrad: Vec<Vec<Option<T>>>,
}

/// Interpret per-executor streams against the pipeline dependency rules,
/// calling `f(cell, dep_a, dep_b)` exactly once per cell when its
/// dependencies have completed.  With model stage k = chunk·S + stage:
///
/// * `F(k, m)`: `dep_a` = completion of `F(k−1, m)` (`None` at k = 0);
///   `dep_b` is `None`;
/// * `B(k, m)`: `dep_a` = completion of this model stage's own
///   `F(k, m)`; `dep_b` = completion of `B(k+1, m)` (`None` at the last
///   model stage);
/// * `W(k, m)`: `dep_a` = own `F(k, m)`, `dep_b` = own `B(k, m)`.
///
/// `f` returns the cell's own completion value (`()` for pure
/// validation, a finish *time* for the DES).  Errors on deadlock,
/// duplicate ops, or missing ops.  This is the single dependency oracle:
/// the schedule validator and the DES simulator call it directly, and the
/// real stage-parallel executor ([`exec`]) realizes the identical rules
/// structurally (per-executor in-order streams + blocking channels).
pub fn execute_streams<T: Clone, F>(
    streams: &[Vec<Cell>],
    micros: usize,
    mut f: F,
) -> Result<ScheduleTrace<T>, String>
where
    F: FnMut(Cell, Option<&T>, Option<&T>) -> T,
{
    let stages = streams.len();
    let chunks = streams
        .iter()
        .flatten()
        .map(|c| c.chunk + 1)
        .max()
        .unwrap_or(1);
    let k_total = stages * chunks;
    let mut fwd: Vec<Vec<Option<T>>> = vec![vec![None; micros]; k_total];
    let mut bwd: Vec<Vec<Option<T>>> = vec![vec![None; micros]; k_total];
    let mut wgrad: Vec<Vec<Option<T>>> = vec![vec![None; micros]; k_total];
    let mut has_w = false;
    let mut idx = vec![0usize; stages];
    let total: usize = streams.iter().map(|s| s.len()).sum();
    let mut executed = 0usize;
    while executed < total {
        let mut progressed = false;
        for s in 0..stages {
            while idx[s] < streams[s].len() {
                let c = streams[s][idx[s]];
                if c.stage != s {
                    return Err(format!(
                        "stream {s} carries a cell for stage {}",
                        c.stage
                    ));
                }
                if c.micro >= micros {
                    return Err(format!(
                        "cell micro {} out of range (micros {micros})",
                        c.micro
                    ));
                }
                let k = c.model_stage(stages);
                // Dependency completion values (None = not ready yet).
                let deps: Option<(Option<T>, Option<T>)> = match c.op {
                    OpKind::F => {
                        if k == 0 {
                            Some((None, None))
                        } else {
                            fwd[k - 1][c.micro].clone().map(|t| (Some(t), None))
                        }
                    }
                    OpKind::B => match fwd[k][c.micro].clone() {
                        None => None,
                        Some(own) => {
                            if k == k_total - 1 {
                                Some((Some(own), None))
                            } else {
                                bwd[k + 1][c.micro]
                                    .clone()
                                    .map(|d| (Some(own), Some(d)))
                            }
                        }
                    },
                    OpKind::W => match (
                        fwd[k][c.micro].clone(),
                        bwd[k][c.micro].clone(),
                    ) {
                        (Some(fo), Some(bo)) => Some((Some(fo), Some(bo))),
                        _ => None,
                    },
                };
                let Some((dep_a, dep_b)) = deps else { break };
                let slot = match c.op {
                    OpKind::F => &mut fwd[k][c.micro],
                    OpKind::B => &mut bwd[k][c.micro],
                    OpKind::W => {
                        has_w = true;
                        &mut wgrad[k][c.micro]
                    }
                };
                if slot.is_some() {
                    return Err(format!(
                        "duplicate {:?} op for model stage {k} micro {}",
                        c.op, c.micro
                    ));
                }
                *slot = Some(f(c, dep_a.as_ref(), dep_b.as_ref()));
                idx[s] += 1;
                executed += 1;
                progressed = true;
            }
        }
        if !progressed {
            return Err(format!("schedule deadlock at {executed}/{total} ops"));
        }
    }
    let unwrap_all = |table: Vec<Vec<Option<T>>>, what: &str| {
        let mut out = Vec::with_capacity(table.len());
        for (k, row) in table.into_iter().enumerate() {
            let mut r = Vec::with_capacity(row.len());
            for (m, v) in row.into_iter().enumerate() {
                match v {
                    Some(v) => r.push(v),
                    None => {
                        return Err(format!(
                            "missing {what} op for model stage {k} micro {m}"
                        ))
                    }
                }
            }
            out.push(r);
        }
        Ok(out)
    };
    // A schedule that splits ANY backward must split them all: the
    // executor derives fused-vs-split per model stage from the stream,
    // and a half-split stage would drop weight gradients.
    if has_w {
        for (k, row) in wgrad.iter().enumerate() {
            for (m, v) in row.iter().enumerate() {
                if v.is_none() {
                    return Err(format!(
                        "schedule splits backwards but model stage {k} \
                         micro {m} has no weight-grad op"
                    ));
                }
            }
        }
    }
    Ok(ScheduleTrace {
        fwd: unwrap_all(fwd, "forward")?,
        bwd: unwrap_all(bwd, "backward")?,
        wgrad,
    })
}

/// Validity check used by executors and property tests: within each
/// executor ops are ordered, forward of (k, m) precedes forward of
/// (k+1, m), backward of (k, m) precedes backward of (k−1, m), the
/// backward of the last model stage follows its forward, and weight-grad
/// ops follow their own backward.
pub fn validate_schedule(streams: &[Vec<Cell>], micros: usize) -> Result<(), String> {
    execute_streams(streams, micros, |_c, _a, _b| ()).map(|_| ())
}

/// True when the streams split the backward into B + W cells (the
/// executor then routes weight-grad work to the W cells).
pub fn splits_backward(streams: &[Vec<Cell>]) -> bool {
    streams.iter().flatten().any(|c| c.op == OpKind::W)
}

/// Number of virtual-stage chunks per executor encoded in the streams.
pub fn virtual_stages_of(streams: &[Vec<Cell>]) -> usize {
    streams
        .iter()
        .flatten()
        .map(|c| c.chunk + 1)
        .max()
        .unwrap_or(1)
}

/// Partition L layers over M stages (equal split required, as in aot.py).
pub fn layers_per_stage(n_layers: usize, stages: usize) -> Result<usize, String> {
    if stages == 0 || n_layers % stages != 0 {
        return Err(format!("{n_layers} layers not divisible by {stages} stages"));
    }
    Ok(n_layers / stages)
}

/// Ideal-pipeline bubble fraction for a fill-drain (GPipe/1F1B) schedule
/// — the legacy helper; [`ScheduleKind::ideal_bubble_fraction`] covers
/// every schedule.
pub fn bubble_fraction(stages: usize, micros: usize) -> f64 {
    ScheduleKind::OneFOneB.ideal_bubble_fraction(stages, 1, micros)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::props;

    #[test]
    fn gpipe_has_all_cells_in_dependency_order() {
        let streams = gpipe_schedule(4, 3);
        let total: usize = streams.iter().map(|s| s.len()).sum();
        assert_eq!(total, 2 * 4 * 3);
        validate_schedule(&streams, 3).unwrap();
    }

    #[test]
    fn one_f_one_b_is_valid_property() {
        props(61).runs(40).check(|g| {
            let stages = g.usize_in(1, 8);
            let micros = g.usize_in(1, 12);
            let streams = one_f_one_b_schedule(stages, micros);
            validate_schedule(&streams, micros).map_err(|e| e)
        });
    }

    #[test]
    fn interleaved_is_valid_over_grid() {
        // Exhaustive (stages <= 6, micros <= 12, v <= 3) grid; v > 1
        // needs micros % stages == 0.
        for stages in 1..=6usize {
            for v in 1..=3usize {
                for micros in 1..=12usize {
                    let r = interleaved_1f1b_schedule(stages, v, micros);
                    if v > 1 && micros % stages != 0 {
                        assert!(r.is_err(), "S={stages} v={v} M={micros}");
                        continue;
                    }
                    let streams = r.unwrap();
                    validate_schedule(&streams, micros).unwrap_or_else(|e| {
                        panic!("S={stages} v={v} M={micros}: {e}")
                    });
                    let total: usize = streams.iter().map(|s| s.len()).sum();
                    assert_eq!(total, 2 * stages * v * micros);
                    assert_eq!(virtual_stages_of(&streams), v);
                    assert!(!splits_backward(&streams));
                }
            }
        }
    }

    #[test]
    fn zero_bubble_is_valid_over_grid() {
        for stages in 1..=6usize {
            for micros in 1..=12usize {
                let streams = zero_bubble_schedule(stages, micros);
                validate_schedule(&streams, micros)
                    .unwrap_or_else(|e| panic!("S={stages} M={micros}: {e}"));
                let total: usize = streams.iter().map(|s| s.len()).sum();
                assert_eq!(total, 3 * stages * micros);
                assert!(splits_backward(&streams));
                // Every W follows its own B within the stream.
                for stream in &streams {
                    for (i, c) in stream.iter().enumerate() {
                        if c.op == OpKind::W {
                            let b_pos = stream
                                .iter()
                                .position(|x| {
                                    x.op == OpKind::B && x.micro == c.micro
                                })
                                .unwrap();
                            assert!(b_pos < i);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn one_f_one_b_bounds_in_flight_activations() {
        let stages = 4;
        let micros = 12;
        let streams = one_f_one_b_schedule(stages, micros);
        for (s, stream) in streams.iter().enumerate() {
            let mut live: i64 = 0;
            let mut peak: i64 = 0;
            for c in stream {
                match c.op {
                    OpKind::F => live += 1,
                    OpKind::B => live -= 1,
                    OpKind::W => {}
                }
                peak = peak.max(live);
            }
            let bound = (stages - s) as i64;
            assert!(peak <= bound, "stage {s}: peak {peak} > {bound}");
        }
    }

    #[test]
    fn zero_bubble_backfills_the_drain() {
        // Stage 0 of S=4, M=8: the drain must alternate B and W (the
        // back-fill), not run all Bs then all Ws.
        let streams = zero_bubble_schedule(4, 8);
        let s0 = &streams[0];
        let first_b = s0.iter().position(|c| c.op == OpKind::B).unwrap();
        // Deep warm-up: 2·(S−1) forwards before the first input-grad.
        assert_eq!(first_b, 2 * 3 + 1);
        let drain: Vec<OpKind> = s0
            .iter()
            .skip_while(|c| c.op != OpKind::W)
            .map(|c| c.op)
            .collect();
        assert!(drain.windows(2).any(|w| w == [OpKind::W, OpKind::B]));
        assert_eq!(s0.last().unwrap().op, OpKind::W);
    }

    #[test]
    fn stage0_of_1f1b_interleaves() {
        let streams = one_f_one_b_schedule(3, 6);
        let s0: Vec<bool> = streams[0].iter().map(|c| c.is_forward()).collect();
        // warm-up of 2 forwards, then alternating, then drain.
        assert_eq!(s0[0..2], [true, true]);
        assert!(s0.windows(2).any(|w| w == [true, false]));
        assert_eq!(s0.last(), Some(&false));
    }

    #[test]
    fn interleaved_chunks_cover_all_model_stages() {
        let (stages, v, micros) = (3usize, 2usize, 6usize);
        let streams = interleaved_1f1b_schedule(stages, v, micros).unwrap();
        let trace = execute_streams(&streams, micros, |_c, _a, _b| ()).unwrap();
        assert_eq!(trace.fwd.len(), stages * v);
        assert_eq!(trace.bwd.len(), stages * v);
        // Executor s runs chunks {0, 1} only, each covering all micros.
        for (s, stream) in streams.iter().enumerate() {
            for c in stream {
                assert_eq!(c.stage, s);
                assert!(c.chunk < v);
            }
        }
    }

    #[test]
    fn schedule_kind_parses_and_generates() {
        assert_eq!(ScheduleKind::parse("1f1b").unwrap(), ScheduleKind::OneFOneB);
        assert_eq!(ScheduleKind::parse("GPipe").unwrap(), ScheduleKind::GPipe);
        assert_eq!(
            ScheduleKind::parse("zero-bubble").unwrap(),
            ScheduleKind::ZeroBubble
        );
        assert_eq!(ScheduleKind::parse("zb").unwrap(), ScheduleKind::ZeroBubble);
        assert_eq!(
            ScheduleKind::parse("interleaved").unwrap(),
            ScheduleKind::Interleaved
        );
        assert!(ScheduleKind::parse("dualpipe").is_err());

        for kind in ScheduleKind::all() {
            let streams = kind.streams(4, 1, 8).unwrap();
            validate_schedule(&streams, 8).unwrap();
            assert_eq!(ScheduleKind::parse(kind.name()).unwrap(), kind);
        }
        // v > 1 only for interleaved; micros must divide.
        assert!(ScheduleKind::OneFOneB.streams(4, 2, 8).is_err());
        assert!(ScheduleKind::Interleaved.streams(4, 2, 6).is_err());
        let il = ScheduleKind::Interleaved.streams(4, 2, 8).unwrap();
        validate_schedule(&il, 8).unwrap();
    }

    #[test]
    fn ideal_bubble_fractions_order_the_schedules() {
        // The worked S=8, M=8 example from the README: 46.7% fill-drain,
        // ~15.6% interleaved v=3, ~0% ZB-H1.
        let fd = ScheduleKind::OneFOneB.ideal_bubble_fraction(8, 1, 8);
        assert!((fd - 7.0 / 15.0).abs() < 1e-12);
        assert_eq!(fd, ScheduleKind::GPipe.ideal_bubble_fraction(8, 1, 8));
        let il = ScheduleKind::Interleaved.ideal_bubble_fraction(8, 3, 8);
        assert!((il - (7.0 / 3.0) / 15.0).abs() < 1e-12);
        let zb = ScheduleKind::ZeroBubble.ideal_bubble_fraction(8, 1, 8);
        assert!(fd > il && il > zb);
        assert_eq!(zb, 0.0);
    }

    #[test]
    fn bubble_shrinks_with_more_microbatches() {
        assert!(bubble_fraction(8, 1) > bubble_fraction(8, 32));
        assert!((bubble_fraction(8, 32) - 7.0 / 39.0).abs() < 1e-12);
        assert_eq!(bubble_fraction(1, 4), 0.0);
    }

    #[test]
    fn layer_partitioning() {
        assert_eq!(layers_per_stage(12, 4).unwrap(), 3);
        assert!(layers_per_stage(10, 4).is_err());
        assert!(layers_per_stage(4, 0).is_err());
    }

    #[test]
    fn execute_streams_yields_dependency_consistent_trace() {
        let streams = one_f_one_b_schedule(3, 4);
        let mut clock = 0usize;
        let trace = execute_streams(&streams, 4, |_c, a, b| {
            clock += 1;
            assert!(a.map_or(true, |&x| x < clock));
            assert!(b.map_or(true, |&x| x < clock));
            clock
        })
        .unwrap();
        for s in 0..3 {
            for m in 0..4 {
                assert!(trace.fwd[s][m] < trace.bwd[s][m]);
                if s > 0 {
                    assert!(trace.fwd[s - 1][m] < trace.fwd[s][m]);
                }
                if s < 2 {
                    assert!(trace.bwd[s + 1][m] < trace.bwd[s][m]);
                }
                assert!(trace.wgrad[s][m].is_none());
            }
        }
    }

    #[test]
    fn execute_streams_orders_weight_grads_after_backwards() {
        let streams = zero_bubble_schedule(4, 6);
        let mut clock = 0usize;
        let trace = execute_streams(&streams, 6, |_c, a, b| {
            clock += 1;
            assert!(a.map_or(true, |&x| x < clock));
            assert!(b.map_or(true, |&x| x < clock));
            clock
        })
        .unwrap();
        for k in 0..4 {
            for m in 0..6 {
                let w = trace.wgrad[k][m].unwrap();
                assert!(trace.bwd[k][m] < w);
                assert!(trace.fwd[k][m] < trace.bwd[k][m]);
            }
        }
    }

    #[test]
    fn deadlock_detection_catches_bad_schedule() {
        // Backward before its forward on the last stage.
        let streams = vec![vec![Cell::b(0, 0, 0), Cell::f(0, 0, 0)]];
        assert!(validate_schedule(&streams, 1).is_err());
        // W before its B deadlocks too.
        let streams = vec![vec![
            Cell::f(0, 0, 0),
            Cell::w(0, 0, 0),
            Cell::b(0, 0, 0),
        ]];
        assert!(validate_schedule(&streams, 1).is_err());
        // Duplicate op is an error, not a silent overwrite.
        let streams = vec![vec![
            Cell::f(0, 0, 0),
            Cell::f(0, 0, 0),
            Cell::b(0, 0, 0),
        ]];
        assert!(validate_schedule(&streams, 1).is_err());
        // A half-split schedule (one B has a W, the other doesn't) is
        // rejected.
        let streams = vec![vec![
            Cell::f(0, 0, 0),
            Cell::f(0, 0, 1),
            Cell::b(0, 0, 0),
            Cell::w(0, 0, 0),
            Cell::b(0, 0, 1),
        ]];
        assert!(validate_schedule(&streams, 2).is_err());
    }
}
