//! Metrics: per-outer-step records from the trainers (loss, tokens, wire
//! bytes, simulated comm/compute time) and CSV/JSON export consumed by the
//! benches and EXPERIMENTS.md tables.

use crate::util::json::{obj, Json};
use std::io::Write;

#[derive(Clone, Debug)]
pub struct StepRecord {
    pub outer_step: usize,
    /// Mean training loss over this outer step's inner steps.
    pub loss: f32,
    /// Local (inner) steps executed in this outer step.
    pub inner_steps: usize,
    pub tokens: u64,
    /// Bytes one worker put on the WAN for this outer step.
    pub wire_bytes: u64,
    /// Achieved compression ratio for this sync.
    pub compression_ratio: f64,
    /// Rank used by the adaptive controller (0 = n/a).
    pub rank: usize,
    /// Wall-clock seconds spent in compute for this outer step.
    pub compute_secs: f64,
    /// *Modeled* WAN communication seconds for this outer step
    /// (ring/PS time at the configured bandwidth).
    pub comm_secs: f64,
    /// Modeled elapsed for the step after overlap policy is applied.
    pub elapsed_secs: f64,
}

#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub name: String,
    pub records: Vec<StepRecord>,
    pub final_eval_loss: Option<f32>,
}

impl RunMetrics {
    pub fn new(name: impl Into<String>) -> Self {
        RunMetrics { name: name.into(), ..Default::default() }
    }

    pub fn push(&mut self, r: StepRecord) {
        self.records.push(r);
    }

    pub fn total_tokens(&self) -> u64 {
        self.records.iter().map(|r| r.tokens).sum()
    }

    pub fn total_wire_bytes(&self) -> u64 {
        self.records.iter().map(|r| r.wire_bytes).sum()
    }

    pub fn total_elapsed(&self) -> f64 {
        self.records.iter().map(|r| r.elapsed_secs).sum()
    }

    /// Modeled throughput in tokens/s (the Fig. 4 metric).
    pub fn tokens_per_sec(&self) -> f64 {
        let t = self.total_elapsed();
        if t > 0.0 {
            self.total_tokens() as f64 / t
        } else {
            0.0
        }
    }

    pub fn last_loss(&self) -> Option<f32> {
        self.final_eval_loss
            .or_else(|| self.records.last().map(|r| r.loss))
    }

    /// Loss curve as (cumulative inner step, loss) pairs.
    pub fn loss_curve(&self) -> Vec<(usize, f32)> {
        let mut out = Vec::with_capacity(self.records.len());
        let mut steps = 0usize;
        for r in &self.records {
            steps += r.inner_steps;
            out.push((steps, r.loss));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "outer_step,loss,inner_steps,tokens,wire_bytes,compression_ratio,rank,compute_secs,comm_secs,elapsed_secs\n",
        );
        for r in &self.records {
            s.push_str(&format!(
                "{},{},{},{},{},{:.3},{},{:.6},{:.6},{:.6}\n",
                r.outer_step,
                r.loss,
                r.inner_steps,
                r.tokens,
                r.wire_bytes,
                r.compression_ratio,
                r.rank,
                r.compute_secs,
                r.comm_secs,
                r.elapsed_secs
            ));
        }
        s
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("name", Json::from(self.name.as_str())),
            (
                "final_loss",
                self.last_loss().map(|l| Json::Num(l as f64)).unwrap_or(Json::Null),
            ),
            ("tokens", Json::from(self.total_tokens() as usize)),
            ("wire_bytes", Json::from(self.total_wire_bytes() as usize)),
            ("elapsed_secs", Json::Num(self.total_elapsed())),
            ("tokens_per_sec", Json::Num(self.tokens_per_sec())),
            (
                "loss_curve",
                Json::Arr(
                    self.loss_curve()
                        .into_iter()
                        .map(|(s, l)| {
                            Json::Arr(vec![Json::from(s), Json::Num(l as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn write_csv(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }
}

/// Fixed-width table printer shared by the benches (paper-style rows).
pub struct Table {
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (c, cell) in cells.iter().enumerate() {
                out.push_str(&format!("{:<w$}  ", cell, w = widths[c]));
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        out.push_str(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  "),
        );
        out.push('\n');
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, loss: f32, tokens: u64, secs: f64) -> StepRecord {
        StepRecord {
            outer_step: step,
            loss,
            inner_steps: 10,
            tokens,
            wire_bytes: 100,
            compression_ratio: 8.0,
            rank: 4,
            compute_secs: secs * 0.8,
            comm_secs: secs * 0.2,
            elapsed_secs: secs,
        }
    }

    #[test]
    fn throughput_and_totals() {
        let mut m = RunMetrics::new("t");
        m.push(rec(0, 5.0, 1000, 2.0));
        m.push(rec(1, 4.0, 1000, 2.0));
        assert_eq!(m.total_tokens(), 2000);
        assert_eq!(m.tokens_per_sec(), 500.0);
        assert_eq!(m.last_loss(), Some(4.0));
        assert_eq!(m.loss_curve(), vec![(10, 5.0), (20, 4.0)]);
    }

    #[test]
    fn csv_and_json_roundtrip() {
        let mut m = RunMetrics::new("t");
        m.push(rec(0, 5.0, 10, 1.0));
        let csv = m.to_csv();
        assert!(csv.lines().count() == 2);
        assert!(csv.contains("outer_step"));
        let j = m.to_json();
        assert_eq!(j.get("tokens").unwrap().as_usize(), Some(10));
        // JSON serializes and re-parses.
        let re = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(re.get("name").unwrap().as_str(), Some("t"));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Configuration", "Loss", "Throughput"]);
        t.row(&["Full DiLoCoX".into(), "4.20".into(), "3728".into()]);
        t.row(&["AllReduce".into(), "3.90".into(), "10.4".into()]);
        let s = t.render();
        assert!(s.contains("Full DiLoCoX"));
        assert_eq!(s.lines().count(), 4);
        let widths: Vec<usize> = s.lines().map(|l| l.len()).collect();
        assert!(widths[0] >= widths[2] - 1);
    }
}
