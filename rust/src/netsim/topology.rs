//! Cluster topology for the decentralized-training simulation: C clusters
//! (DP groups on opposite sides of slow WAN links), each with `pp` workers
//! chained by fast intra-cluster links — the paper's Figure 1 layout.

use super::{Link, Resource};
use crate::config::NetworkConfig;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WorkerId {
    pub cluster: usize,
    pub stage: usize,
}

#[derive(Debug)]
pub struct Topology {
    pub clusters: usize,
    pub stages: usize,
    /// One compute resource per worker (GPU stream).
    pub gpus: Vec<Resource>,
    /// Dedicated comm engine per worker (NCCL-style: comm kernels run on
    /// copy engines and genuinely overlap with compute).
    pub comm_engines: Vec<Resource>,
    /// Intra-cluster stage-to-stage links: index [cluster][stage] connects
    /// stage -> stage+1.
    pub intra: Vec<Vec<Link>>,
    /// Per-cluster wrap-around link (last stage -> stage 0), used only by
    /// interleaved virtual-stage schedules that hand the last model chunk's
    /// activations back to executor 0.
    pub wrap: Vec<Link>,
    /// One shared WAN "bus" per ring direction between adjacent clusters:
    /// inter[c] connects cluster c -> (c+1) % C.
    pub inter: Vec<Link>,
}

impl Topology {
    pub fn new(net: &NetworkConfig, stages: usize) -> Self {
        let clusters = net.clusters;
        let mut gpus = Vec::new();
        let mut comm_engines = Vec::new();
        let mut intra = Vec::new();
        for c in 0..clusters {
            let mut links = Vec::new();
            for s in 0..stages {
                gpus.push(Resource::new(format!("gpu[c{c},s{s}]")));
                comm_engines.push(Resource::new(format!("nic[c{c},s{s}]")));
                if s + 1 < stages {
                    links.push(Link::new(
                        format!("intra[c{c},{s}->{}]", s + 1),
                        net.intra_bw_gbps,
                        0.01, // 10 µs in-cluster latency
                    ));
                }
            }
            intra.push(links);
        }
        let wrap = (0..clusters)
            .map(|c| {
                Link::new(
                    format!("intra[c{c},{}->0]", stages.saturating_sub(1)),
                    net.intra_bw_gbps,
                    0.01,
                )
            })
            .collect();
        let inter = (0..clusters)
            .map(|c| {
                Link::new(
                    format!("wan[{c}->{}]", (c + 1) % clusters),
                    net.inter_bw_gbps,
                    net.latency_ms,
                )
            })
            .collect();
        Topology { clusters, stages, gpus, comm_engines, intra, wrap, inter }
    }

    pub fn gpu_index(&self, w: WorkerId) -> usize {
        w.cluster * self.stages + w.stage
    }

    pub fn gpu(&mut self, w: WorkerId) -> &mut Resource {
        let i = self.gpu_index(w);
        &mut self.gpus[i]
    }

    pub fn comm_engine(&mut self, w: WorkerId) -> &mut Resource {
        let i = self.gpu_index(w);
        &mut self.comm_engines[i]
    }

    /// Link used by stage s -> s+1 inside cluster c.
    pub fn intra_link(&mut self, c: usize, s: usize) -> &mut Link {
        &mut self.intra[c][s]
    }

    /// Wrap link used by the last stage -> stage 0 inside cluster c.
    pub fn wrap_link(&mut self, c: usize) -> &mut Link {
        &mut self.wrap[c]
    }

    /// WAN link leaving cluster c toward (c+1) % C.
    pub fn inter_link(&mut self, c: usize) -> &mut Link {
        &mut self.inter[c]
    }

    /// Total bytes that crossed WAN links.
    pub fn wan_bytes(&self) -> u64 {
        self.inter.iter().map(|l| l.bytes_total).sum()
    }

    pub fn worker_ids(&self) -> Vec<WorkerId> {
        let mut out = Vec::with_capacity(self.clusters * self.stages);
        for cluster in 0..self.clusters {
            for stage in 0..self.stages {
                out.push(WorkerId { cluster, stage });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net(clusters: usize) -> NetworkConfig {
        NetworkConfig {
            clusters,
            inter_bw_gbps: 1.0,
            intra_bw_gbps: 100.0,
            latency_ms: 30.0,
        }
    }

    #[test]
    fn builds_paper_figure1_layout() {
        // 2 clusters x 8 stages = 16 workers (paper Fig. 1 example).
        let t = Topology::new(&net(2), 8);
        assert_eq!(t.gpus.len(), 16);
        assert_eq!(t.intra[0].len(), 7);
        assert_eq!(t.inter.len(), 2);
        assert_eq!(t.worker_ids().len(), 16);
    }

    #[test]
    fn gpu_indexing_is_bijective() {
        let t = Topology::new(&net(3), 4);
        let mut seen = std::collections::HashSet::new();
        for w in t.worker_ids() {
            assert!(seen.insert(t.gpu_index(w)));
        }
        assert_eq!(seen.len(), 12);
    }

    #[test]
    fn wan_byte_accounting() {
        let mut t = Topology::new(&net(2), 1);
        t.inter_link(0).transfer(0.0, 1000);
        t.inter_link(1).transfer(0.0, 500);
        assert_eq!(t.wan_bytes(), 1500);
    }

    #[test]
    fn intra_much_faster_than_inter() {
        let mut t = Topology::new(&net(2), 2);
        let bytes = 100_000_000;
        let (_, intra_end) = t.intra_link(0, 0).transfer(0.0, bytes);
        let (_, inter_end) = t.inter_link(0).transfer(0.0, bytes);
        assert!(inter_end > 50.0 * intra_end);
    }
}
