//! Fault-aware cost model hook for the DES substrate: deterministic
//! (Pcg32-seeded) WAN churn applied to link transfers — stragglers that
//! multiply a transfer's duration and drops that force a retransmission.
//! The same seed reproduces the same perturbation schedule, so simulated
//! churn scenarios (107B sync under packet loss, slow-cluster rounds) are
//! replayable, mirroring the live fault injection in
//! [`crate::transport::faulty`].

use crate::util::rng::Pcg32;

/// Per-link fault model; draw one [`factor`](LinkFaultModel::factor) per
/// transfer (the draw order is the schedule, so keep one model per link).
#[derive(Clone, Debug)]
pub struct LinkFaultModel {
    /// Probability a transfer hits a straggling path.
    pub straggler_prob: f64,
    /// Duration multiplier when straggling (e.g. 4.0 = 4× slower).
    pub straggler_mult: f64,
    /// Probability a transfer is dropped once and retransmitted (2×).
    pub drop_prob: f64,
    rng: Pcg32,
}

impl LinkFaultModel {
    pub fn new(seed: u64, straggler_prob: f64, straggler_mult: f64, drop_prob: f64) -> Self {
        LinkFaultModel {
            straggler_prob,
            straggler_mult,
            drop_prob,
            rng: Pcg32::new(seed, 0xfa17),
        }
    }

    /// A model that never perturbs (factor always 1.0).
    pub fn clean(seed: u64) -> Self {
        Self::new(seed, 0.0, 1.0, 0.0)
    }

    /// Duration multiplier for the next transfer (≥ 1.0).
    pub fn factor(&mut self) -> f64 {
        let mut f = 1.0;
        if self.rng.next_f64() < self.straggler_prob {
            f *= self.straggler_mult.max(1.0);
        }
        if self.rng.next_f64() < self.drop_prob {
            f *= 2.0; // one retransmission
        }
        f
    }

    /// Expected duration multiplier (for closed-form sanity checks).
    pub fn expected_factor(&self) -> f64 {
        let s = 1.0 + self.straggler_prob * (self.straggler_mult.max(1.0) - 1.0);
        let d = 1.0 + self.drop_prob;
        s * d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netsim::Link;

    #[test]
    fn same_seed_same_schedule() {
        let mut a = LinkFaultModel::new(9, 0.3, 4.0, 0.1);
        let mut b = LinkFaultModel::new(9, 0.3, 4.0, 0.1);
        let fa: Vec<f64> = (0..64).map(|_| a.factor()).collect();
        let fb: Vec<f64> = (0..64).map(|_| b.factor()).collect();
        assert_eq!(fa, fb);
        let mut c = LinkFaultModel::new(10, 0.3, 4.0, 0.1);
        let fc: Vec<f64> = (0..64).map(|_| c.factor()).collect();
        assert_ne!(fa, fc);
    }

    #[test]
    fn clean_model_is_identity() {
        let mut m = LinkFaultModel::clean(1);
        for _ in 0..16 {
            assert_eq!(m.factor(), 1.0);
        }
        assert_eq!(m.expected_factor(), 1.0);
    }

    #[test]
    fn empirical_factor_tracks_expectation() {
        let mut m = LinkFaultModel::new(123, 0.25, 3.0, 0.2);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| m.factor()).sum::<f64>() / n as f64;
        let expect = m.expected_factor();
        assert!(
            (mean - expect).abs() < 0.05 * expect,
            "mean={mean} expect={expect}"
        );
    }

    #[test]
    fn faulty_transfer_inflates_duration() {
        let mut link = Link::new("wan", 1.0, 0.0);
        // Always-straggling model: every transfer takes 4x.
        let mut m = LinkFaultModel::new(5, 1.0, 4.0, 0.0);
        let (s, e) = link.transfer_with_faults(0.0, 1_000_000_000, &mut m);
        assert_eq!(s, 0.0);
        assert!((e - 32.0).abs() < 1e-9, "e={e}"); // 8 s clean, 4x
        assert_eq!(link.bytes_total, 1_000_000_000);
    }
}
