//! Discrete-event simulation substrate for the throughput experiments.
//!
//! The model is event-graph / resource-constrained scheduling: every task
//! (a stage compute, a link transfer) declares the virtual time it becomes
//! *ready* (max over dependency finish times) and a *duration*; resources
//! (a GPU, a network link) serialize the tasks that claim them.  Completion
//! times fall out deterministically — no coroutines, no wall clock, and a
//! 4000-outer-step 160-worker run simulates in milliseconds (DESIGN.md
//! §Perf target).
//!
//! Links model `latency + bytes/bandwidth` with serialization, i.e. the
//! same quantity the paper controls with `tc` on the 1 Gbps inter-cluster
//! path.

pub mod faults;
pub mod topology;

pub use faults::LinkFaultModel;
pub use topology::{Topology, WorkerId};

/// Virtual time in seconds.
pub type SimTime = f64;

/// A serializing resource (GPU stream, NIC, shared link).
#[derive(Clone, Debug)]
pub struct Resource {
    pub name: String,
    busy_until: SimTime,
    pub busy_total: f64,
    pub tasks: u64,
}

impl Resource {
    pub fn new(name: impl Into<String>) -> Self {
        Resource { name: name.into(), busy_until: 0.0, busy_total: 0.0, tasks: 0 }
    }

    /// Claim the resource for `dur` seconds no earlier than `ready`.
    /// Returns (start, end).
    pub fn acquire(&mut self, ready: SimTime, dur: f64) -> (SimTime, SimTime) {
        debug_assert!(dur >= 0.0);
        let start = ready.max(self.busy_until);
        let end = start + dur;
        self.busy_until = end;
        self.busy_total += dur;
        self.tasks += 1;
        (start, end)
    }

    pub fn busy_until(&self) -> SimTime {
        self.busy_until
    }

    /// Utilization over [0, horizon].
    pub fn utilization(&self, horizon: SimTime) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            (self.busy_total / horizon).min(1.0)
        }
    }
}

/// A point-to-point (or bus) link: latency + serialized bandwidth,
/// with byte accounting.
#[derive(Clone, Debug)]
pub struct Link {
    pub res: Resource,
    pub bandwidth_bytes_per_s: f64,
    pub latency_s: f64,
    pub bytes_total: u64,
}

impl Link {
    pub fn new(name: impl Into<String>, gbps: f64, latency_ms: f64) -> Self {
        Link {
            res: Resource::new(name),
            bandwidth_bytes_per_s: gbps * 1e9 / 8.0,
            latency_s: latency_ms * 1e-3,
            bytes_total: 0,
        }
    }

    pub fn transfer_duration(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bytes_per_s
    }

    /// Schedule a transfer that becomes ready at `ready`; returns (start, end).
    pub fn transfer(&mut self, ready: SimTime, bytes: u64) -> (SimTime, SimTime) {
        self.bytes_total += bytes;
        let dur = self.transfer_duration(bytes);
        self.res.acquire(ready, dur)
    }

    /// Fault-aware transfer: like [`transfer`](Link::transfer) but the
    /// duration is perturbed by the deterministic churn model (stragglers,
    /// retransmissions) — the DES-side counterpart of the live fault
    /// injection in [`crate::transport::faulty`].
    pub fn transfer_with_faults(
        &mut self,
        ready: SimTime,
        bytes: u64,
        faults: &mut faults::LinkFaultModel,
    ) -> (SimTime, SimTime) {
        self.bytes_total += bytes;
        let dur = self.transfer_duration(bytes) * faults.factor();
        self.res.acquire(ready, dur)
    }
}

/// Span log for bubble/overlap analysis and (optional) trace dumps.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub spans: Vec<Span>,
    pub enabled: bool,
}

#[derive(Clone, Debug)]
pub struct Span {
    pub resource: String,
    pub label: String,
    pub start: SimTime,
    pub end: SimTime,
}

impl Trace {
    pub fn record(&mut self, resource: &str, label: &str, start: SimTime, end: SimTime) {
        if self.enabled {
            self.spans.push(Span {
                resource: resource.to_string(),
                label: label.to_string(),
                start,
                end,
            });
        }
    }

    /// Total busy time on one resource within [0, horizon].
    pub fn busy_on(&self, resource: &str, horizon: SimTime) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.resource == resource && s.start < horizon)
            .map(|s| s.end.min(horizon) - s.start)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resource_serializes_overlapping_tasks() {
        let mut r = Resource::new("gpu0");
        let (s1, e1) = r.acquire(0.0, 2.0);
        let (s2, e2) = r.acquire(1.0, 3.0); // ready before r is free
        assert_eq!((s1, e1), (0.0, 2.0));
        assert_eq!((s2, e2), (2.0, 5.0)); // waited for the resource
        let (s3, _) = r.acquire(10.0, 1.0); // idle gap
        assert_eq!(s3, 10.0);
        assert_eq!(r.busy_total, 6.0);
    }

    #[test]
    fn link_transfer_time_is_latency_plus_serialization() {
        let mut l = Link::new("wan", 1.0, 30.0); // 1 Gbps, 30 ms
        // 1 GB at 1 Gbps = 8 s + 0.03 s latency.
        let (s, e) = l.transfer(0.0, 1_000_000_000);
        assert_eq!(s, 0.0);
        assert!((e - 8.03).abs() < 1e-9, "e={e}");
        assert_eq!(l.bytes_total, 1_000_000_000);
    }

    #[test]
    fn paper_2_4_1_comm_overhead_reproduced() {
        // §2.4.1: 100B params FP32, C=3 clusters, ring allreduce segment
        // between clusters = 2*(C-1)/C * theta ≈ 533.3 GB; at 1 Gbps that
        // is ~1.18 hours.
        let theta_bytes: f64 = 100e9 * 4.0;
        let c: f64 = 3.0;
        let wire = 2.0 * (c - 1.0) / c * theta_bytes;
        assert!((wire / 1e9 - 533.33).abs() < 0.01, "wire={wire}");
        let mut l = Link::new("wan", 1.0, 0.0);
        let (_, e) = l.transfer(0.0, wire as u64);
        let hours = e / 3600.0;
        assert!((hours - 1.185).abs() < 0.01, "hours={hours}");
    }

    #[test]
    fn utilization_accounting() {
        let mut r = Resource::new("g");
        r.acquire(0.0, 1.0);
        r.acquire(3.0, 1.0);
        assert!((r.utilization(4.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn trace_busy_accounting() {
        let mut t = Trace { enabled: true, ..Default::default() };
        t.record("gpu0", "fwd", 0.0, 1.0);
        t.record("gpu0", "bwd", 2.0, 4.0);
        t.record("gpu1", "fwd", 0.0, 9.0);
        assert_eq!(t.busy_on("gpu0", 10.0), 3.0);
        assert_eq!(t.busy_on("gpu0", 3.0), 2.0); // clipped at horizon
    }
}
