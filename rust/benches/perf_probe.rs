//! Perf probe (§Perf in EXPERIMENTS.md): the repo's repeatable baseline
//! harness.  Micro-measurements of every hot path — the in-memory ring
//! AllReduce, the compression reducer group, the DES simulator (with the
//! Fig. 4 throughput rows), PJRT step execution when an artifact bundle
//! is on disk, and the tracing-overhead probe (a thread-mode elastic
//! fleet run twice, traced off and on, asserting bit-identical results).
//!
//!     cargo bench --bench perf_probe -- --out BENCH_7.json --name BENCH_7
//!
//! Prints human-readable lines AND (with `--out`) writes one
//! machine-readable JSON document (`schema: "dilocox-bench/v1"`) so CI
//! can archive a baseline per commit.  All inputs are fixed-seed;
//! timings vary with the machine, shapes and byte counts do not.
//! Iterations are small (one shared CPU core); numbers are for relative
//! tracking between optimization steps, not absolute benchmarking.
//!
//! Two diff modes over committed baselines (no benches run):
//!
//!     cargo bench --bench perf_probe -- --compare BENCH_6.json BENCH_7.json
//!     cargo bench --bench perf_probe -- --check   BENCH_7.json BENCH_7.ci.json
//!
//! `--compare A B` prints per-section speedup ratios (A_ms / B_ms, so
//! > 1.0x means B is faster).  `--check A B` is the CI regression gate:
//! it exits 1 only when a *guarded* row (the ring and reducer timings —
//! the hot paths this repo optimizes) regressed by more than 2x, so
//! shared-runner noise on the unguarded rows never fails a build.

use dilocox::comm::ring::build_ring;
use dilocox::compress::{GroupReducer, Method};
use dilocox::config::{Algo, NetworkConfig};
use dilocox::obs;
use dilocox::pipeline::exec::{
    local_stage_rings, run_pipeline, PipelineRunOpts, SyntheticPipeline,
};
use dilocox::pipeline::{self, OpKind, ScheduleKind};
use dilocox::runtime::manifest::ParamEntry;
use dilocox::runtime::Runtime;
use dilocox::sim::{self, ScaleConfig, SimAlgo};
use dilocox::transport::elastic::{run_elastic, ElasticConfig, SpawnMode};
use dilocox::transport::RingTransport;
use dilocox::util::json::{obj, Json};
use dilocox::util::rng::Pcg32;
use std::time::Instant;

/// Every randomized input in this harness derives from this seed.
const SEED: u64 = 2026;

fn main() {
    // Manual flag scan: cargo-bench appends its own arguments
    // (`--bench`), so tolerate anything we don't recognize.
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let two_paths = |i: usize, flag: &str| -> (String, String) {
        match (argv.get(i + 1), argv.get(i + 2)) {
            (Some(a), Some(b)) => (a.clone(), b.clone()),
            _ => {
                eprintln!("{flag} needs two baseline paths: {flag} A.json B.json");
                std::process::exit(2);
            }
        }
    };
    if let Some(i) = argv.iter().position(|a| a == "--compare") {
        let (a, b) = two_paths(i, "--compare");
        std::process::exit(compare_baselines(&a, &b, f64::INFINITY));
    }
    if let Some(i) = argv.iter().position(|a| a == "--check") {
        let (a, b) = two_paths(i, "--check");
        std::process::exit(compare_baselines(&a, &b, 2.0));
    }
    let out_path = argv
        .iter()
        .position(|a| a == "--out")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let name = argv
        .iter()
        .position(|a| a == "--name")
        .and_then(|i| argv.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_7".to_string());

    let mut sections: Vec<(&str, Json)> = Vec::new();
    sections.push(("ring_allreduce", bench_ring()));
    sections.push(("ring_topology", bench_ring_topology()));
    sections.push(("reduce", bench_reduce()));
    sections.push(("des", bench_des()));
    sections.push(("pipeline_schedule", bench_pipeline_schedule()));
    sections.push(("step_single", bench_step_single()));
    sections.push(("traced_overhead", bench_traced_overhead()));

    if let Some(path) = out_path {
        let doc = obj(vec![
            ("schema", Json::Str("dilocox-bench/v1".to_string())),
            ("bench", Json::Str(name)),
            ("seed", Json::Num(SEED as f64)),
            ("sections", Json::Obj(
                sections
                    .into_iter()
                    .map(|(k, v)| (k.to_string(), v))
                    .collect(),
            )),
        ]);
        match std::fs::write(&path, doc.to_string_pretty() + "\n") {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("writing {path}: {e}");
                std::process::exit(1);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Baseline diffing (--compare / --check)
// ---------------------------------------------------------------------------

fn load_baseline(path: &str) -> Json {
    let s = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("reading {path}: {e}");
        std::process::exit(2);
    });
    let doc = Json::parse(&s).unwrap_or_else(|e| {
        eprintln!("parsing {path}: {e}");
        std::process::exit(2);
    });
    match doc.get("schema").and_then(Json::as_str) {
        Some("dilocox-bench/v1") => doc,
        other => {
            eprintln!("{path}: not a dilocox-bench/v1 document ({other:?})");
            std::process::exit(2);
        }
    }
}

/// Flatten a baseline into `(row key, milliseconds, guarded)` — guarded
/// rows (ring + reducer, the optimized hot paths) are the only ones the
/// `--check` gate fails on.
fn baseline_metrics(doc: &Json) -> Vec<(String, f64, bool)> {
    let mut out = Vec::new();
    if let Some(rows) = doc.path("sections.ring_allreduce").and_then(Json::as_arr) {
        for r in rows {
            if let (Some(c), Some(e), Some(ms)) = (
                r.get("members").and_then(Json::as_usize),
                r.get("elems").and_then(Json::as_usize),
                r.get("ms_per_op").and_then(Json::as_f64),
            ) {
                out.push((format!("ring_allreduce[C={c},{e}].ms_per_op"), ms, true));
            }
        }
    }
    if let Some(rows) = doc.path("sections.ring_topology").and_then(Json::as_arr)
    {
        for r in rows {
            if let (Some(p), Some(t), Some(ms)) = (
                r.get("payload").and_then(Json::as_str),
                r.get("topology").and_then(Json::as_str),
                r.get("wan_ms").and_then(Json::as_f64),
            ) {
                out.push((format!("ring_topology[{p},{t}].wan_ms"), ms, true));
            }
        }
    }
    if let Some(rows) = doc.path("sections.reduce").and_then(Json::as_arr) {
        for r in rows {
            if let (Some(m), Some(ms)) = (
                r.get("method").and_then(Json::as_str),
                r.get("ms_per_sync").and_then(Json::as_f64),
            ) {
                out.push((format!("reduce[{m}].ms_per_sync"), ms, true));
            }
        }
    }
    if let Some(ms) = doc.path("sections.des.ms_per_run").and_then(Json::as_f64) {
        out.push(("des.ms_per_run".to_string(), ms, false));
    }
    if let Some(rows) =
        doc.path("sections.pipeline_schedule.rows").and_then(Json::as_arr)
    {
        for r in rows {
            let Some(s) = r.get("schedule").and_then(Json::as_str) else {
                continue;
            };
            // Deterministic schedule math: guarded.  Wall clock: not.
            if let Some(mk) = r.get("modeled_makespan").and_then(Json::as_f64) {
                out.push((
                    format!("pipeline_schedule[{s}].modeled_makespan"),
                    mk,
                    true,
                ));
            }
            if let Some(ms) = r.get("ms_per_round").and_then(Json::as_f64) {
                out.push((
                    format!("pipeline_schedule[{s}].ms_per_round"),
                    ms,
                    false,
                ));
            }
        }
    }
    if let Some(r) = doc
        .path("sections.pipeline_schedule.zb_speedup_vs_1f1b_modeled")
        .and_then(Json::as_f64)
    {
        // Stored inverted (1/speedup) so "bigger is worse" matches the
        // gate's regression direction: a future schedule change that
        // erodes the zero-bubble win shows up as this row growing.
        out.push((
            "pipeline_schedule.inv_zb_speedup_modeled".to_string(),
            1.0 / r,
            true,
        ));
    }
    if let Some(ms) = doc
        .path("sections.step_single.ms_wall_per_call")
        .and_then(Json::as_f64)
    {
        out.push(("step_single.ms_wall_per_call".to_string(), ms, false));
    }
    for k in ["off_secs", "on_secs"] {
        if let Some(s) = doc
            .path(&format!("sections.traced_overhead.{k}"))
            .and_then(Json::as_f64)
        {
            out.push((format!("traced_overhead.{k}_ms"), s * 1e3, false));
        }
    }
    out
}

/// Print the A-vs-B speedup table; with a finite `tolerance`, exit
/// nonzero when any guarded row of B is more than `tolerance`x slower
/// than A.
fn compare_baselines(a_path: &str, b_path: &str, tolerance: f64) -> i32 {
    let (a_doc, b_doc) = (load_baseline(a_path), load_baseline(b_path));
    let a_name = a_doc
        .get("bench")
        .and_then(Json::as_str)
        .unwrap_or(a_path)
        .to_string();
    let b_name = b_doc
        .get("bench")
        .and_then(Json::as_str)
        .unwrap_or(b_path)
        .to_string();
    let a = baseline_metrics(&a_doc);
    let b = baseline_metrics(&b_doc);
    println!(
        "{:<44} {:>12} {:>12} {:>9}",
        "section (ms)", a_name, b_name, "speedup"
    );
    let mut regressed: Vec<String> = Vec::new();
    for (key, av, guarded) in &a {
        let Some((_, bv, _)) = b.iter().find(|(k, _, _)| k == key) else {
            println!("{key:<44} {av:>12.2} {:>12} {:>9}", "-", "-");
            continue;
        };
        let speedup = av / bv; // > 1 ⇒ B is faster than A
        let flag = if *guarded && *bv > av * tolerance {
            regressed.push(key.clone());
            "  REGRESSED"
        } else {
            ""
        };
        println!("{key:<44} {av:>12.2} {bv:>12.2} {speedup:>8.2}x{flag}");
    }
    for (key, bv, _) in &b {
        if !a.iter().any(|(k, _, _)| k == key) {
            println!("{key:<44} {:>12} {bv:>12.2} {:>9}", "-", "-");
        }
    }
    if tolerance.is_finite() {
        if regressed.is_empty() {
            println!(
                "check OK: no guarded section regressed past {tolerance:.1}x"
            );
            0
        } else {
            eprintln!(
                "check FAILED: {} guarded section(s) regressed past \
                 {tolerance:.1}x: {}",
                regressed.len(),
                regressed.join(", ")
            );
            1
        }
    } else {
        0
    }
}

/// In-memory chunked ring AllReduce: ms/op and the §2.4.1 wire factor.
fn bench_ring() -> Json {
    let mut rows = Vec::new();
    for (members, elems) in [(4usize, 1usize << 16), (8, 1 << 14)] {
        let ring = build_ring(members);
        let meter = std::sync::Arc::clone(&ring[0].meter);
        let iters = 8usize;
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for mut m in ring {
                scope.spawn(move || {
                    let mut rng = Pcg32::seed_from(SEED + m.rank as u64);
                    let mut buf = vec![0.0f32; elems];
                    rng.fill_normal(&mut buf, 0.0, 1.0);
                    for _ in 0..iters {
                        m.allreduce_sum(&mut buf).unwrap();
                    }
                });
            }
        });
        let ms_per_op = 1e3 * t0.elapsed().as_secs_f64() / iters as f64;
        let wire_per_op = meter.total() / iters as u64;
        println!(
            "ring allreduce (C={members}, {elems} f32): {ms_per_op:.2} ms/op, \
             {wire_per_op} wire bytes/op"
        );
        rows.push(obj(vec![
            ("members", Json::Num(members as f64)),
            ("elems", Json::Num(elems as f64)),
            ("ms_per_op", Json::Num(ms_per_op)),
            ("wire_bytes_per_op", Json::Num(wire_per_op as f64)),
        ]));
    }
    Json::Arr(rows)
}

/// Reduction-topology comparison at netsim-modeled heterogeneous links:
/// four 107B clusters interleaved over two sites (paper 1 Gbps WAN,
/// 100 Gbps LAN, 30 ms), flat vs bandwidth-reordered vs hierarchical
/// two-level, for the raw fp32 and the DiLoCoX-compressed sync payload.
/// Fully deterministic — payload byte math plus the link model, no wall
/// clock — so a regenerated baseline matches the committed one exactly
/// and the `--check` gate guards the topology math itself.
fn bench_ring_topology() -> Json {
    let scale = ScaleConfig::qwen_107b();
    let net = NetworkConfig::paper_1gbps(4);
    let site_of = [0usize, 1, 0, 1];
    let dx = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
    let mut rows = Vec::new();
    for (label, payload) in [
        ("fp32", (4.0 * scale.params) as u64),
        (
            "dilocox",
            sim::sync_payload_bytes(scale.params, scale.d_hidden, &dx.method),
        ),
    ] {
        for r in sim::reduce_topology_rows(payload, &net, &site_of) {
            println!(
                "ring_topology[{label},{}]: order {:?}, {} WAN bytes/member, \
                 {:.1} s modeled WAN sync",
                r.topology, r.order, r.wan_bytes_per_member, r.wan_secs
            );
            rows.push(obj(vec![
                ("payload", Json::Str(label.to_string())),
                ("topology", Json::Str(r.topology.to_string())),
                (
                    "order",
                    Json::Arr(
                        r.order.iter().map(|&i| Json::Num(i as f64)).collect(),
                    ),
                ),
                (
                    "wan_bytes_per_member",
                    Json::Num(r.wan_bytes_per_member as f64),
                ),
                ("wan_ms", Json::Num(1e3 * r.wan_secs)),
            ]));
        }
    }
    Json::Arr(rows)
}

/// The reducer group over a synthetic square-matrix spec — no artifact
/// bundle needed, so this section always runs.
fn bench_reduce() -> Json {
    let side = 128usize;
    let mats = 4usize;
    let n = side * side * mats;
    let spec: Vec<ParamEntry> = (0..mats)
        .map(|i| ParamEntry {
            name: format!("w{i}"),
            shape: vec![side, side],
            offset: i * side * side,
        })
        .collect();
    let mut rng = Pcg32::seed_from(SEED);
    let mk = |rng: &mut Pcg32| {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.0, 1e-2);
        v
    };
    let deltas = vec![mk(&mut rng), mk(&mut rng)];
    let mut rows = Vec::new();
    for (label, method) in [
        ("none", Method::None),
        ("quant_int4", Method::Quant { q_bits: 4 }),
        (
            "lowrank64_int4",
            Method::LowRankQuant { rank: 64, q_bits: 4 },
        ),
        (
            "cocktail",
            Method::Cocktail { random_ratio: 0.1, topk_ratio: 0.08, q_bits: 4 },
        ),
    ] {
        let mut red = GroupReducer::new(method, 7);
        let warm = red.reduce(&deltas, &spec, 0); // basis init
        let iters = 5u64;
        let t0 = Instant::now();
        for s in 0..iters {
            red.reduce(&deltas, &spec, s + 1);
        }
        let ms = 1e3 * t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "reduce[{label}] (D=2, {n} params): {ms:.1} ms/sync, \
             {} payload bytes ({:.1}x)",
            warm.payload_bytes, warm.ratio
        );
        rows.push(obj(vec![
            ("method", Json::Str(label.to_string())),
            ("params", Json::Num(n as f64)),
            ("ms_per_sync", Json::Num(ms)),
            ("payload_bytes", Json::Num(warm.payload_bytes as f64)),
            ("ratio", Json::Num(warm.ratio)),
        ]));
    }
    Json::Arr(rows)
}

/// DES runtime cost plus the Fig. 4 throughput rows it produces — the
/// paper-shape numbers a baseline diff should flag first.
fn bench_des() -> Json {
    let scale = ScaleConfig::qwen_107b();
    let algo = SimAlgo::paper_setting(Algo::DiLoCoX, &scale);
    let iters = 10usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        sim::simulate(&scale, &algo, 32);
    }
    let ms_per_run = 1e3 * t0.elapsed().as_secs_f64() / iters as f64;
    println!(
        "DES simulate (107B, 32 outer rounds): {ms_per_run:.1} ms/run"
    );

    let mut fig4 = Vec::new();
    for scale in [ScaleConfig::opt_1_3b(), ScaleConfig::qwen_107b()] {
        for r in sim::figure4_row(&scale, 16) {
            fig4.push(obj(vec![
                ("scale", Json::Str(scale.name.clone())),
                ("algo", Json::Str(r.algo.name().to_string())),
                ("tokens_per_sec", Json::Num(r.tokens_per_sec)),
                ("oom", Json::Bool(r.oom)),
            ]));
        }
    }
    obj(vec![
        ("ms_per_run", Json::Num(ms_per_run)),
        ("fig4", Json::Arr(fig4)),
    ])
}

/// Unit-cost list-scheduled makespan of a schedule's op streams: a full
/// stage forward costs 1, a fused backward 2 (input + weight grads), a
/// split backward 1 + 1; interleaved chunk ops cost 1/v of a full-stage
/// op (the chunk is 1/v of the model).  Fully deterministic — the same
/// dependency oracle the executor validates against, no wall clock —
/// so these rows reproduce bit-for-bit and the `--check` gate guards
/// the schedule math itself.
fn modeled_makespan(kind: ScheduleKind, execs: usize, v: usize, micros: usize) -> f64 {
    let streams = kind.streams(execs, v, micros).expect("schedule");
    let split = pipeline::splits_backward(&streams);
    let mut clock = vec![0.0f64; execs];
    pipeline::execute_streams(
        &streams,
        micros,
        |c, a: Option<&f64>, b: Option<&f64>| {
            let dur = match c.op {
                OpKind::F => 1.0,
                OpKind::B if split => 1.0,
                OpKind::B => 2.0,
                OpKind::W => 1.0,
            } / v as f64;
            let ready =
                a.copied().unwrap_or(0.0).max(b.copied().unwrap_or(0.0));
            let start = clock[c.stage].max(ready);
            clock[c.stage] = start + dur;
            clock[c.stage]
        },
    )
    .expect("valid schedule");
    clock.into_iter().fold(0.0, f64::max)
}

/// The four microbatch schedules head-to-head on the real threaded
/// executor (S = 4 executors, M = 8 microbatches, dp = 1 so compute
/// dominates): deterministic modeled makespans (guarded rows) plus
/// measured wall time and the trace-measured bubble fraction per round.
/// Every row drives the same total model and burn work — the
/// interleaved row cuts it into 2x more chunks of half the size, the
/// Megatron virtual-stage semantics.
fn bench_pipeline_schedule() -> Json {
    const EXECS: usize = 4;
    const MICROS: usize = 8;
    let specs = [
        (ScheduleKind::GPipe, 1usize),
        (ScheduleKind::OneFOneB, 1),
        (ScheduleKind::Interleaved, 2),
        (ScheduleKind::ZeroBubble, 1),
    ];
    let mut rows = Vec::new();
    let mut wall_ms: Vec<(ScheduleKind, f64)> = Vec::new();
    for (kind, v) in specs {
        let makespan = modeled_makespan(kind, EXECS, v, MICROS);
        // Per-executor busy time is schedule-invariant (3 cost units per
        // microbatch), so makespan overhang IS the bubble.
        let work = 3.0 * MICROS as f64;
        let modeled_bubble = (makespan - work) / makespan;
        let ideal_bubble = kind.ideal_bubble_fraction(EXECS, v, MICROS);

        // Same model, same burn work on every row: EXECS*v chunks of
        // dim 512/v, each op burning 200/v passes.
        let wl = SyntheticPipeline::new(EXECS * v, MICROS, 512 / v, SEED)
            .with_compute_passes(200 / v);
        let opts = PipelineRunOpts {
            rounds: 2,
            local_steps: 4,
            schedule: kind,
            virtual_stages: v,
            ..PipelineRunOpts::default()
        };
        obs::set_enabled(true);
        obs::drain();
        let t0 = Instant::now();
        let out =
            run_pipeline(&wl, 1, local_stage_rings(1, EXECS * v), &opts)
                .expect("schedule bench run");
        let wall = t0.elapsed().as_secs_f64();
        let events = obs::drain();
        obs::set_enabled(false);
        let acct = obs::report::round_accounting(&events);
        let measured_bubble = if acct.is_empty() {
            0.0
        } else {
            acct.iter().map(|a| a.bubble_fraction).sum::<f64>()
                / acct.len() as f64
        };
        let ms_per_round = 1e3 * wall / opts.rounds as f64;
        wall_ms.push((kind, ms_per_round));
        println!(
            "pipeline_schedule[{}] (S={EXECS}, M={MICROS}, v={v}): modeled \
             makespan {makespan:.2}, bubble modeled {modeled_bubble:.3} / \
             measured {measured_bubble:.3}, {ms_per_round:.1} ms/round, \
             final eval {:.3e}",
            kind.name(),
            out.final_eval
        );
        rows.push(obj(vec![
            ("schedule", Json::Str(kind.name().to_string())),
            ("virtual_stages", Json::Num(v as f64)),
            ("modeled_makespan", Json::Num(makespan)),
            ("modeled_bubble", Json::Num(modeled_bubble)),
            ("ideal_bubble", Json::Num(ideal_bubble)),
            ("ms_per_round", Json::Num(ms_per_round)),
            ("measured_bubble", Json::Num(measured_bubble)),
        ]));
    }
    let ms_of = |k: ScheduleKind| {
        wall_ms.iter().find(|(kk, _)| *kk == k).map(|&(_, ms)| ms).unwrap()
    };
    let modeled_speedup = modeled_makespan(
        ScheduleKind::OneFOneB,
        EXECS,
        1,
        MICROS,
    ) / modeled_makespan(ScheduleKind::ZeroBubble, EXECS, 1, MICROS);
    let measured_speedup =
        ms_of(ScheduleKind::OneFOneB) / ms_of(ScheduleKind::ZeroBubble);
    // The headline claim, asserted on the deterministic model (33/27 at
    // S=4, M=8); the measured ratio is reported but never gated — wall
    // clock on a shared runner is noise.
    assert!(
        modeled_speedup >= 1.2,
        "zero-bubble modeled speedup {modeled_speedup:.3} < 1.2x over 1F1B"
    );
    println!(
        "pipeline_schedule: zero-bubble vs 1f1b speedup {modeled_speedup:.3}x \
         modeled, {measured_speedup:.3}x measured"
    );
    obj(vec![
        ("executors", Json::Num(EXECS as f64)),
        ("micros", Json::Num(MICROS as f64)),
        ("rows", Json::Arr(rows)),
        ("zb_speedup_vs_1f1b_modeled", Json::Num(modeled_speedup)),
        ("zb_speedup_vs_1f1b_measured", Json::Num(measured_speedup)),
    ])
}

/// PJRT step execution through the L2 artifact — skipped (not failed)
/// when no bundle is on disk, so the harness stays runnable everywhere.
fn bench_step_single() -> Json {
    let dir = format!("{}/artifacts/small", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&dir).exists() {
        println!("step_single: skipped (artifacts/small missing — `make artifacts`)");
        return obj(vec![
            ("skipped", Json::Bool(true)),
            (
                "reason",
                Json::Str("artifacts/small missing".to_string()),
            ),
        ]);
    }
    let rt = Runtime::load(&dir).unwrap();
    rt.precompile(&["step_single", "eval_single"]).unwrap();
    let man = &rt.manifest;
    let params = man.read_f32(&man.init["single"].file).unwrap();
    let n_tok = man.dims.microbatch * man.dims.seq_len;
    let tokens = vec![3i32; n_tok];
    let labels = vec![4i32; n_tok];
    rt.step_single(&params, &tokens, &labels).unwrap(); // warmup
    let iters = 20usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        rt.step_single(&params, &tokens, &labels).unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = rt.stats();
    let (execs, exec_secs) = st.per_program["step_single"];
    let ms_wall = 1e3 * wall / iters as f64;
    let ms_exec = 1e3 * exec_secs / execs as f64;
    println!(
        "step_single (small, {} params): {ms_wall:.2} ms/call wall, \
         {ms_exec:.2} ms/call in PJRT exec ({execs} calls)",
        man.param_count
    );
    obj(vec![
        ("skipped", Json::Bool(false)),
        ("params", Json::Num(man.param_count as f64)),
        ("ms_wall_per_call", Json::Num(ms_wall)),
        ("ms_exec_per_call", Json::Num(ms_exec)),
        ("compile_secs", Json::Num(st.compile_seconds)),
    ])
}

/// The zero-overhead-when-disabled claim, measured: the same thread-mode
/// elastic fleet runs traced-off then traced-on; the results must be
/// bit-for-bit identical and the wall-clock delta is the trace cost.
fn bench_traced_overhead() -> Json {
    let mut cfg = ElasticConfig::quadratic(2, 4, 64);
    cfg.transport.ring_timeout_ms = 1000;
    cfg.transport.connect_timeout_ms = 5000;
    cfg.wall_timeout_ms = 60_000;

    let t0 = Instant::now();
    let off = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
    let off_secs = t0.elapsed().as_secs_f64();

    cfg.trace = true;
    let t1 = Instant::now();
    let on = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
    let on_secs = t1.elapsed().as_secs_f64();

    assert_eq!(off.final_params, on.final_params, "tracing perturbed numerics");
    assert_eq!(
        off.total_wire_bytes, on.total_wire_bytes,
        "tracing perturbed the wire ledger"
    );
    println!(
        "traced overhead (2 workers x 4 rounds, thread mode): \
         off {off_secs:.3} s, on {on_secs:.3} s, {} events; bit-identical",
        on.trace_events.len()
    );
    obj(vec![
        ("off_secs", Json::Num(off_secs)),
        ("on_secs", Json::Num(on_secs)),
        ("trace_events", Json::Num(on.trace_events.len() as f64)),
        ("bit_identical", Json::Bool(true)),
    ])
}
