//! Perf probe (§Perf in EXPERIMENTS.md): micro-measurements of the three
//! hot paths — PJRT step execution (L2 artifact through the L3 runtime),
//! the compression reducer (L3-native PowerSGD), and the DES simulator.
//!
//!     cargo bench --bench perf_probe
//!
//! Iterations are small (one shared CPU core); numbers are for relative
//! tracking between optimization steps, not absolute benchmarking.

use dilocox::compress::{GroupReducer, Method};
use dilocox::runtime::Runtime;
use dilocox::sim::{self, ScaleConfig, SimAlgo};
use dilocox::util::rng::Pcg32;
use std::time::Instant;

fn main() {
    let dir = format!("{}/artifacts/small", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&dir).exists() {
        eprintln!("artifacts/small missing — run `make artifacts`");
        std::process::exit(1);
    }

    // ---- L2/L3: step_single execution ------------------------------------
    let rt = Runtime::load(&dir).unwrap();
    rt.precompile(&["step_single", "eval_single"]).unwrap();
    let man = &rt.manifest;
    let params = man.read_f32(&man.init["single"].file).unwrap();
    let n_tok = man.dims.microbatch * man.dims.seq_len;
    let tokens = vec![3i32; n_tok];
    let labels = vec![4i32; n_tok];
    // warmup
    rt.step_single(&params, &tokens, &labels).unwrap();
    let iters = 20;
    let t0 = Instant::now();
    for _ in 0..iters {
        rt.step_single(&params, &tokens, &labels).unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = rt.stats();
    let (execs, exec_secs) = st.per_program["step_single"];
    println!(
        "step_single (small, {} params): {:.2} ms/call wall, {:.2} ms/call in PJRT exec ({} calls), host overhead {:.1}%",
        man.param_count,
        1e3 * wall / iters as f64,
        1e3 * exec_secs / execs as f64,
        execs,
        100.0 * (wall / iters as f64 - exec_secs / execs as f64)
            / (wall / iters as f64)
    );
    println!(
        "compile: {:.2} s total for {} programs",
        st.compile_seconds,
        st.per_program.len()
    );

    // ---- L3: compression reducer ------------------------------------------
    let spec = man.param_specs["single"].clone();
    let mut rng = Pcg32::seed_from(1);
    let mk = |rng: &mut Pcg32| {
        let mut v = vec![0.0f32; man.param_count];
        rng.fill_normal(&mut v, 0.0, 1e-2);
        v
    };
    let deltas = vec![mk(&mut rng), mk(&mut rng)];
    for (label, method) in [
        ("lowrank r=64 + int4", Method::LowRankQuant { rank: 64, q_bits: 4 }),
        ("int4 quantize", Method::Quant { q_bits: 4 }),
        ("cocktail 0.1/0.08/4", Method::Cocktail { random_ratio: 0.1, topk_ratio: 0.08, q_bits: 4 }),
    ] {
        let mut red = GroupReducer::new(method, 7);
        red.reduce(&deltas, &spec, 0); // warm (basis init)
        let iters = 5;
        let t0 = Instant::now();
        for s in 0..iters {
            red.reduce(&deltas, &spec, s + 1);
        }
        println!(
            "reduce[{label}] (D=2, {} params): {:.1} ms/sync",
            man.param_count,
            1e3 * t0.elapsed().as_secs_f64() / iters as f64
        );
    }

    // ---- DES simulator ------------------------------------------------------
    let scale = ScaleConfig::qwen_107b();
    let algo = SimAlgo::paper_setting(dilocox::config::Algo::DiLoCoX, &scale);
    let t0 = Instant::now();
    let iters = 20;
    for _ in 0..iters {
        sim::simulate(&scale, &algo, 32);
    }
    println!(
        "DES simulate (107B, 80 stages x 160 microbatches, 32 outer rounds): {:.1} ms/run",
        1e3 * t0.elapsed().as_secs_f64() / iters as f64
    );
}
