//! Table 1 reproduction — the DiLoCoX ablation at Qwen1.5-107B:
//!   loss column      → real-numerics runs on the `small` preset with the
//!                      107B hyperparameter ratios (same substitution as
//!                      Fig 3(b); DESIGN.md),
//!   throughput column → DES simulation at the true 107B scale.
//!
//! Scale knobs: DILOCOX_BENCH_OUTER [12], DILOCOX_BENCH_H [10].
//!
//!     cargo bench --bench table1_ablation

use dilocox::config::{Algo, ExperimentConfig};
use dilocox::metrics::Table;
use dilocox::report::paper;
use dilocox::runtime::Runtime;
use dilocox::sim;
use dilocox::train::{run_with_runtime, RunOpts};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let dir = format!("{}/artifacts/small", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&dir).exists() {
        eprintln!("artifacts/small missing — run `make artifacts`");
        std::process::exit(1);
    }
    let outer = env_usize("DILOCOX_BENCH_OUTER", 12);
    let h = env_usize("DILOCOX_BENCH_H", 10);
    let rt = Runtime::load(&dir).unwrap();
    rt.precompile(&["step_single", "eval_single"]).unwrap();

    let mk = |name: &str| -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default_for("small", Algo::DiLoCoX);
        cfg.artifacts_dir = dir.clone();
        cfg.train.outer_steps = outer;
        cfg.train.local_steps = h;
        cfg.train.inner_lr = 2e-3;
        cfg.train.outer_lr = 0.5;
        cfg.train.outer_momentum = 0.5;
        cfg.compression.rank = 64;
        cfg.compression.adaptive = true;
        cfg.compression.rank_window = 5;
        match name {
            "Full DiLoCoX" => {}
            "w/o Overlap" => cfg.train.overlap = false,
            "w/o Compression" => {
                cfg.train.overlap = false;
                cfg.compression.enabled = false;
                cfg.compression.adaptive = false;
            }
            "AllReduce" => {
                cfg.algo = Algo::AllReduce;
                cfg.train.overlap = false;
                cfg.compression = dilocox::config::CompressionConfig::none();
                cfg.train.local_steps = h; // same inner budget
            }
            _ => unreachable!(),
        }
        cfg
    };

    // Throughput column from the 107B DES.
    let sim_rows = sim::table1_throughput(16);

    println!(
        "Table 1 — Qwen1.5-107B ablation (loss: small-preset proxy, {} inner steps; throughput: 107B DES)\n",
        outer * h
    );
    let mut t = Table::new(&[
        "Configuration",
        "loss (proxy)",
        "paper loss",
        "tok/s (sim)",
        "paper tok/s",
    ]);
    let opts = RunOpts { quiet: true, eval_batches: 4, ..Default::default() };
    let mut losses = Vec::new();
    for (name, paper_loss, paper_tps) in paper::TABLE1.map(|(n, l, p)| (n, l, p)) {
        let cfg = mk(name);
        let out = run_with_runtime(&cfg, &opts, &rt).expect("run failed");
        let loss = out.metrics.final_eval_loss.unwrap();
        let sim_tps = sim_rows
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, r)| r.tokens_per_sec)
            .unwrap();
        t.row(&[
            name.to_string(),
            format!("{loss:.4}"),
            format!("{paper_loss:.2}"),
            dilocox::report::fmt_tps(sim_tps),
            dilocox::report::fmt_tps(paper_tps),
        ]);
        losses.push((name, loss, sim_tps));
    }
    println!("{}", t.render());

    // Shape checks: loss monotone ordering AllReduce <= w/o Comp <= DiLoCoX
    // variants; throughput strictly the reverse.
    let get = |n: &str| losses.iter().find(|(x, _, _)| *x == n).unwrap();
    let full = get("Full DiLoCoX");
    let noov = get("w/o Overlap");
    let nocmp = get("w/o Compression");
    let ar = get("AllReduce");
    let mut misses = 0;
    let mut check = |name: &str, ok: bool| {
        println!("  [{}] {name}", if ok { "ok" } else { "MISS" });
        if !ok {
            misses += 1;
        }
    };
    println!("shape checks (paper: 4.20/4.15/4.02/3.90 loss, 3728/2197/1168/10.4 tok/s):");
    check(
        &format!("AllReduce best loss ({:.3})", ar.1),
        ar.1 <= full.1 + 0.05 && ar.1 <= nocmp.1 + 0.05,
    );
    check(
        &format!("w/o Compression <= w/o Overlap + 0.2 ({:.3} vs {:.3})", nocmp.1, noov.1),
        nocmp.1 <= noov.1 + 0.2,
    );
    check(
        &format!("Full within 1.5 of AllReduce ({:.3} vs {:.3})", full.1, ar.1),
        full.1 <= ar.1 + 1.5,
    );
    check(
        "throughput strictly decreasing Full > w/o Ov > w/o Comp > AllReduce",
        full.2 > noov.2 && noov.2 > nocmp.2 && nocmp.2 > ar.2,
    );
    if misses > 0 {
        eprintln!("{misses} shape check(s) missed");
        std::process::exit(1);
    }
}
