//! Figure 4 reproduction: end-to-end throughput of AllReduce, OpenDiLoCo,
//! CocktailSGD and DiLoCoX at OPT-1.3B and Qwen1.5-107B scale over a
//! 1 Gbps WAN — DES simulation with the A800 compute model (DESIGN.md).
//!
//!     cargo bench --bench fig4_throughput -- --json fig4.json
//!
//! `--json path` additionally writes the measured rows as machine-readable
//! JSON (same row schema as perf_probe's `des.fig4` section).

use dilocox::config::Algo;
use dilocox::report::{self, paper, rel_dev};
use dilocox::sim::{self, ScaleConfig};
use dilocox::util::json::{obj, Json};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let json_path = argv
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| argv.get(i + 1))
        .cloned();
    let rounds = 16;
    let mut misses = 0;
    let mut json_rows = Vec::new();

    for scale in [ScaleConfig::opt_1_3b(), ScaleConfig::qwen_107b()] {
        let rows = sim::figure4_row(&scale, rounds);
        let paper_rows: &[(&str, f64)] = if scale.params > 10e9 {
            &paper::FIG4_107B
        } else {
            &paper::FIG4_1_3B
        };
        println!("{}", report::figure4_table(&scale.name, paper_rows, &rows));
        for r in &rows {
            json_rows.push(obj(vec![
                ("scale", Json::Str(scale.name.clone())),
                ("algo", Json::Str(r.algo.name().to_string())),
                ("tokens_per_sec", Json::Num(r.tokens_per_sec)),
                ("oom", Json::Bool(r.oom)),
            ]));
        }

        let get = |a: Algo| rows.iter().find(|r| r.algo == a).unwrap();
        let ar = get(Algo::AllReduce);
        let dx = get(Algo::DiLoCoX);
        let ck = get(Algo::CocktailSgd);
        let od = get(Algo::OpenDiLoCo);

        let speedup = dx.tokens_per_sec / ar.tokens_per_sec;
        let paper_speedup = paper_rows
            .iter()
            .find(|(n, _)| *n == "DiLoCoX")
            .unwrap()
            .1
            / paper_rows.iter().find(|(n, _)| *n == "AllReduce").unwrap().1;
        println!("shape checks:");
        let mut check = |name: &str, ok: bool| {
            println!("  [{}] {name}", if ok { "ok" } else { "MISS" });
            if !ok {
                misses += 1;
            }
        };
        check(
            &format!(
                "DiLoCoX vs AllReduce speedup {speedup:.0}x (paper {paper_speedup:.0}x, within 2x band)"
            ),
            speedup > paper_speedup / 2.0 && speedup < paper_speedup * 2.0,
        );
        check(
            &format!(
                "DiLoCoX > CocktailSGD ({:.0} vs {:.0})",
                dx.tokens_per_sec, ck.tokens_per_sec
            ),
            dx.tokens_per_sec > ck.tokens_per_sec,
        );
        if scale.params > 10e9 {
            check("OpenDiLoCo OOMs at 107B", od.oom);
            check(
                &format!(
                    "AllReduce ~10 tok/s (paper 10.4, got {:.1})",
                    ar.tokens_per_sec
                ),
                rel_dev(ar.tokens_per_sec, 10.4) < 0.5,
            );
        } else {
            check("OpenDiLoCo fits at 1.3B", !od.oom);
        }
        println!();
    }

    println!(
        "headline: DiLoCoX @107B = {:.0}x AllReduce (paper claims 357x)",
        {
            let rows = sim::figure4_row(&ScaleConfig::qwen_107b(), rounds);
            let ar = rows.iter().find(|r| r.algo == Algo::AllReduce).unwrap();
            let dx = rows.iter().find(|r| r.algo == Algo::DiLoCoX).unwrap();
            dx.tokens_per_sec / ar.tokens_per_sec
        }
    );
    if let Some(path) = json_path {
        let doc = obj(vec![
            ("schema", Json::Str("dilocox-bench/v1".to_string())),
            ("bench", Json::Str("fig4_throughput".to_string())),
            ("rows", Json::Arr(json_rows)),
            ("shape_check_misses", Json::Num(misses as f64)),
        ]);
        match std::fs::write(&path, doc.to_string_pretty() + "\n") {
            Ok(()) => println!("wrote {path}"),
            Err(e) => {
                eprintln!("writing {path}: {e}");
                std::process::exit(1);
            }
        }
    }
    if misses > 0 {
        eprintln!("{misses} shape check(s) missed");
        std::process::exit(1);
    }
}
