//! §2.4.1 reproduction — the communication-overhead analysis that
//! motivates DiLoCoX — plus the §2.4.2 compressor design-space comparison
//! and an ablation of the Alg-3 H policy (literal paper rule vs an
//! overlap-matched extension).
//!
//!     cargo bench --bench comm_analysis

use dilocox::compress::{GroupReducer, Method};
use dilocox::config::NetworkConfig;
use dilocox::metrics::Table;
use dilocox::report::paper;
use dilocox::runtime::manifest::ParamEntry;
use dilocox::sim::{self, ScaleConfig, SimAlgo};
use dilocox::util::rng::Pcg32;
use dilocox::util::{fmt_bytes, fmt_secs};

fn main() {
    let mut misses = 0;

    // ---- §2.4.1 worked example -------------------------------------------
    println!("== §2.4.1 communication overhead (100B params, C=3, 1 Gbps) ==");
    let theta: f64 = 100e9;
    let c = 3usize;
    let wire = 2.0 * (c as f64 - 1.0) / c as f64 * theta * 4.0;
    let net = NetworkConfig {
        clusters: c,
        inter_bw_gbps: 1.0,
        intra_bw_gbps: 100.0,
        latency_ms: 0.0,
    };
    let secs = dilocox::comm::ring_allreduce_seconds((theta * 4.0) as u64, &net);
    let local_hours = 500.0 / 3600.0;
    let mut t = Table::new(&["quantity", "measured", "paper"]);
    t.row(&[
        "inter-cluster wire per sync".into(),
        format!("{:.1} GB", wire / 1e9),
        format!("{} GB", paper::COMM_ANALYSIS_GB),
    ]);
    t.row(&[
        "transfer time @1Gbps".into(),
        format!("{:.2} h", secs / 3600.0),
        format!("{} h", paper::COMM_ANALYSIS_HOURS),
    ]);
    t.row(&[
        "local training (H=500 × 1 s)".into(),
        format!("{:.2} h", local_hours),
        "0.13 h".into(),
    ]);
    t.row(&[
        "idle time without overlap".into(),
        format!("{:.2} h", secs / 3600.0 - local_hours),
        "1.04 h".into(),
    ]);
    println!("{}", t.render());
    let ok = (wire / 1e9 - 533.3).abs() < 0.5
        && (secs / 3600.0 - 1.18).abs() < 0.02;
    println!("  [{}] §2.4.1 numbers reproduced\n", if ok { "ok" } else { "MISS" });
    if !ok {
        misses += 1;
    }

    // ---- §2.4.2 compressor design space ----------------------------------
    println!("== §2.4.2 compressor comparison (same pseudo-gradient, D=2) ==");
    let (rows, cols) = (128, 512);
    let n = rows * cols;
    let spec = vec![ParamEntry { name: "w".into(), shape: vec![rows, cols], offset: 0 }];
    let mut rng = Pcg32::seed_from(42);
    let mk = |rng: &mut Pcg32| {
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 0.0, 1.0);
        // add low-rank structure: gradients are never white noise
        for r in 0..rows {
            let s = 1.0 / (1 + r % 8) as f32;
            for c in 0..cols {
                v[r * cols + c] *= s;
            }
        }
        v
    };
    let deltas = vec![mk(&mut rng), mk(&mut rng)];
    let mean: Vec<f32> = (0..n)
        .map(|i| (deltas[0][i] + deltas[1][i]) / 2.0)
        .collect();
    let norm2: f64 = mean.iter().map(|&x| (x as f64).powi(2)).sum();

    let methods: Vec<(&str, Method, bool)> = vec![
        ("fp32 (AllReduce)", Method::None, true),
        ("fp16 (OpenDiLoCo)", Method::Quant { q_bits: 16 }, true),
        ("int4", Method::Quant { q_bits: 4 }, true),
        ("random-k 10%", Method::RandomK { ratio: 0.1 }, true),
        ("top-k 10% (PS)", Method::TopK { ratio: 0.1, q_bits: 0 }, false),
        (
            "lowrank r=16 + int4 (DiLoCoX)",
            Method::LowRankQuant { rank: 16, q_bits: 4 },
            true,
        ),
        (
            "cocktail 0.1/0.08/int4",
            Method::Cocktail { random_ratio: 0.1, topk_ratio: 0.08, q_bits: 4 },
            false,
        ),
    ];
    let mut t = Table::new(&[
        "scheme",
        "ratio",
        "rel l2 err",
        "AllReduce-compatible",
    ]);
    let mut dilocox_err = f64::NAN;
    let mut cocktail_err = f64::NAN;
    for (name, m, arc) in methods {
        let mut red = GroupReducer::new(m, 7);
        let out = red.reduce(&deltas, &spec, 0);
        let err2: f64 = out
            .avg
            .iter()
            .zip(&mean)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum();
        let rel = (err2 / norm2).sqrt();
        if name.contains("DiLoCoX") {
            dilocox_err = rel;
        }
        if name.contains("cocktail") {
            cocktail_err = rel;
        }
        t.row(&[
            name.to_string(),
            format!("{:.0}x", out.ratio),
            format!("{rel:.3}"),
            if arc { "yes".into() } else { "no (PS + double compression)".into() },
        ]);
    }
    println!("{}", t.render());
    let ok = dilocox_err < cocktail_err;
    println!(
        "  [{}] DiLoCoX's balanced scheme beats aggressive sparsification in error\n",
        if ok { "ok" } else { "MISS" }
    );
    if !ok {
        misses += 1;
    }

    // ---- Alg 3 H-policy ablation (extension) ------------------------------
    println!("== adaptive-H policy ablation @107B (extension, DESIGN.md) ==");
    let scale = ScaleConfig::qwen_107b();
    let base = SimAlgo::paper_setting(dilocox::config::Algo::DiLoCoX, &scale);
    let r = sim::simulate(&scale, &base, 16);
    // Literal Alg-3 rule: H_t = H₁·α; at converged rank r_t ≈ r₁/2 → α=0.5.
    let mut literal = base.clone();
    literal.local_steps = (base.local_steps as f64 * 0.5) as usize;
    let r_lit = sim::simulate(&scale, &literal, 16);
    // Overlap-matched extension: smallest H with comm fully hidden.
    let mut matched = base.clone();
    let h_min = (r.comm_secs / r.step_secs).ceil() as usize;
    matched.local_steps = h_min.max(1);
    let r_match = sim::simulate(&scale, &matched, 16);
    let mut t = Table::new(&["policy", "H", "tokens/s", "syncs per 1k steps", "GPU util"]);
    for (name, res, h) in [
        ("paper H₁=125", &r, base.local_steps),
        ("Alg-3 literal (α=0.5)", &r_lit, literal.local_steps),
        ("overlap-matched (extension)", &r_match, matched.local_steps),
    ] {
        t.row(&[
            name.to_string(),
            h.to_string(),
            dilocox::report::fmt_tps(res.tokens_per_sec),
            format!("{:.0}", 1000.0 / h as f64),
            format!("{:.0}%", 100.0 * res.gpu_utilization),
        ]);
    }
    println!("{}", t.render());
    println!(
        "overlap-matched H = ceil(comm/step) = {} hides the {} sync exactly; \
         smaller H means fresher outer updates at the same throughput.",
        h_min,
        fmt_secs(r.comm_secs)
    );
    println!(
        "sync payload at the paper setting: {} ({}x vs fp32)",
        fmt_bytes(r.wire_bytes),
        r.compression_ratio as u64
    );

    if misses > 0 {
        eprintln!("{misses} shape check(s) missed");
        std::process::exit(1);
    }
}
