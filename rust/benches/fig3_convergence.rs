//! Figure 3 reproduction: loss curves for AllReduce / DiLoCoX /
//! OpenDiLoCo / CocktailSGD with the paper's hyperparameter *ratios* on
//! the `small` preset (the 1.3B/107B substitution — DESIGN.md).
//!
//! Part (a) mirrors the OPT-1.3B setting (DiLoCoX = Int4 + H, OpenDiLoCo =
//! fp16 + 4H, Cocktail = rand 0.1 / topk 0.08 / Int4).
//! Part (b) mirrors the Qwen1.5-107B setting (DiLoCoX adds low-rank,
//! Cocktail topk drops to 0.04; OpenDiLoCo is skipped = the paper's OOM).
//!
//! Scale knobs (defaults sized for a single CPU core):
//!   DILOCOX_BENCH_OUTER   outer steps per algorithm   [default 12]
//!   DILOCOX_BENCH_H       DiLoCoX local steps H₁      [default 10]
//! Total inner steps = OUTER × H; the paper's 4000-step runs correspond
//! to OUTER=32, H=125.

use dilocox::config::{Algo, ExperimentConfig};
use dilocox::metrics::Table;
use dilocox::report::paper;
use dilocox::runtime::Runtime;
use dilocox::train::{run_with_runtime, RunOpts, TrainOutcome};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn base_cfg(algo: Algo, dir: &str, outer: usize, h: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default_for("small", algo);
    cfg.artifacts_dir = dir.to_string();
    cfg.train.inner_lr = 2e-3;
    // Outer settings tuned for the short proxy horizon: the paper's
    // 0.7/0.9 Nesterov assumes H=125 and thousands of steps; at a 120-step
    // budget momentum 0.9 compounds over consistent early-training deltas
    // and diverges (recorded in EXPERIMENTS.md §Notes).
    cfg.train.outer_lr = 0.5;
    cfg.train.outer_momentum = 0.5;
    cfg.train.seed = 1234;
    // Same total inner-step budget for every algorithm (paper: fixed
    // 4000 steps).
    match algo {
        Algo::AllReduce | Algo::CocktailSgd => {
            cfg.train.outer_steps = outer;
            cfg.train.local_steps = h;
        }
        Algo::DiLoCoX => {
            cfg.train.outer_steps = outer;
            cfg.train.local_steps = h;
        }
        Algo::OpenDiLoCo => {
            // Paper ratio: H_od = 4 × H_dx (500 vs 125) → 4x fewer syncs.
            cfg.train.outer_steps = (outer / 4).max(1);
            cfg.train.local_steps = h * 4;
        }
    }
    cfg
}

fn run(cfg: &ExperimentConfig, rt: &Runtime) -> TrainOutcome {
    run_with_runtime(cfg, &RunOpts { quiet: true, eval_batches: 4, ..Default::default() }, rt)
        .expect("bench run failed")
}

fn curve_str(out: &TrainOutcome) -> String {
    out.eval_curve
        .iter()
        .map(|(s, l)| format!("{s}:{l:.3}"))
        .collect::<Vec<_>>()
        .join(" ")
}

fn main() {
    let dir = format!("{}/artifacts/small", env!("CARGO_MANIFEST_DIR"));
    if !std::path::Path::new(&dir).exists() {
        eprintln!("artifacts/small missing — run `make artifacts`");
        std::process::exit(1);
    }
    let outer = env_usize("DILOCOX_BENCH_OUTER", 12);
    let h = env_usize("DILOCOX_BENCH_H", 10);
    let rt = Runtime::load(&dir).unwrap();
    rt.precompile(&["step_single", "eval_single"]).unwrap();
    println!(
        "fig3_convergence: small preset, {} total inner steps per algorithm\n",
        outer * h
    );

    // ---------------- part (a): OPT-1.3B setting -------------------------
    println!("== Figure 3(a) proxy — OPT-1.3B hyperparameter ratios ==");
    let mut t = Table::new(&[
        "algorithm",
        "final eval loss",
        "paper loss@4k",
        "gap vs AllReduce (paper)",
        "wire total",
    ]);
    let mut ar_loss = f32::NAN;
    let mut results_a = Vec::new();
    for algo in [Algo::AllReduce, Algo::DiLoCoX, Algo::OpenDiLoCo, Algo::CocktailSgd] {
        let mut cfg = base_cfg(algo, &dir, outer, h);
        if algo == Algo::DiLoCoX {
            // 1.3B row: Int4 only, no low-rank, no adaptive.
            cfg.compression.rank = 0;
            cfg.compression.adaptive = false;
        }
        let out = run(&cfg, &rt);
        let loss = out.metrics.final_eval_loss.unwrap();
        if algo == Algo::AllReduce {
            ar_loss = loss;
        }
        let paper_loss = paper::FIG3A_LOSS
            .iter()
            .find(|(n, _)| *n == algo.name())
            .map(|(_, v)| *v)
            .unwrap();
        let paper_gap = paper_loss - paper::FIG3A_LOSS[0].1;
        t.row(&[
            algo.name().to_string(),
            format!("{loss:.4}"),
            format!("{paper_loss:.2}"),
            format!("{:+.3} ({:+.2})", loss - ar_loss, paper_gap),
            dilocox::util::fmt_bytes(out.metrics.total_wire_bytes()),
        ]);
        results_a.push((algo, out));
    }
    println!("{}", t.render());
    println!("loss curves (inner step : eval loss)");
    for (algo, out) in &results_a {
        println!("  {:<11} {}", algo.name(), curve_str(out));
    }

    // ---------------- part (b): Qwen1.5-107B setting ----------------------
    println!("\n== Figure 3(b) proxy — Qwen1.5-107B hyperparameter ratios ==");
    println!("(OpenDiLoCo omitted: OOM at 107B, see fig4/memory)");
    let mut t = Table::new(&[
        "algorithm",
        "final eval loss",
        "paper loss@4k",
        "gap vs AllReduce (paper)",
        "compression",
    ]);
    let mut ar_loss = f32::NAN;
    let mut results_b = Vec::new();
    for algo in [Algo::AllReduce, Algo::DiLoCoX, Algo::CocktailSgd] {
        let mut cfg = base_cfg(algo, &dir, outer, h);
        if algo == Algo::DiLoCoX {
            // 107B row: low-rank (≈2x on the proxy's width) + Int4 +
            // adaptive controller with window c=5 (paper §4.1.3).
            cfg.compression.rank = 64; // d_model/2 → the paper's "≈2x"
            cfg.compression.adaptive = true;
            cfg.compression.rank_window = 5;
        }
        if algo == Algo::CocktailSgd {
            cfg.compression.topk_ratio = 0.04;
        }
        let out = run(&cfg, &rt);
        let loss = out.metrics.final_eval_loss.unwrap();
        if algo == Algo::AllReduce {
            ar_loss = loss;
        }
        let paper_loss = paper::FIG3B_LOSS
            .iter()
            .find(|(n, _)| *n == algo.name())
            .map(|(_, v)| *v)
            .unwrap();
        let paper_gap = paper_loss - paper::FIG3B_LOSS[0].1;
        let ratio = out
            .metrics
            .records
            .iter()
            .rev()
            .find(|r| r.wire_bytes > 0)
            .map(|r| r.compression_ratio)
            .unwrap_or(1.0);
        t.row(&[
            algo.name().to_string(),
            format!("{loss:.4}"),
            format!("{paper_loss:.2}"),
            format!("{:+.3} ({:+.2})", loss - ar_loss, paper_gap),
            format!("{ratio:.0}x/sync"),
        ]);
        results_b.push((algo, out));
    }
    println!("{}", t.render());
    println!("loss curves (inner step : eval loss)");
    for (algo, out) in &results_b {
        println!("  {:<11} {}", algo.name(), curve_str(out));
    }

    // Shape verdicts (the reproduction claim).
    let loss_of = |rs: &[(Algo, TrainOutcome)], a: Algo| {
        rs.iter()
            .find(|(x, _)| *x == a)
            .unwrap()
            .1
            .metrics
            .final_eval_loss
            .unwrap()
    };
    let a_ar = loss_of(&results_a, Algo::AllReduce);
    let a_dx = loss_of(&results_a, Algo::DiLoCoX);
    let a_od = loss_of(&results_a, Algo::OpenDiLoCo);
    let a_ck = loss_of(&results_a, Algo::CocktailSgd);
    println!("\nshape checks (paper ordering: AR <= DX < OD, CK):");
    println!(
        "  [{}] DiLoCoX within 1.0 of AllReduce   ({a_dx:.3} vs {a_ar:.3})",
        if a_dx <= a_ar + 1.0 { "ok" } else { "MISS" }
    );
    println!(
        "  [{}] DiLoCoX within 1.0 of OpenDiLoCo  ({a_dx:.3} vs {a_od:.3})",
        if a_dx <= a_od + 1.0 { "ok" } else { "MISS" }
    );
    println!(
        "  [{}] DiLoCoX beats CocktailSGD         ({a_dx:.3} vs {a_ck:.3})",
        if a_dx <= a_ck { "ok" } else { "MISS" }
    );
}
