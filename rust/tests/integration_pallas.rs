//! L1→L2→L3 composition proof: the `tiny-pallas` bundle was lowered with
//! the Pallas kernels (interpret=True) on the matmul/attention/quantize/
//! low-rank paths.  Running it through the rust PJRT runtime and matching
//! (a) its own jax goldens and (b) the jnp-lowered `tiny` bundle shows the
//! pallas kernels survive AOT lowering and execute from the coordinator.

use dilocox::runtime::{DType, HostTensor, Runtime};

fn bundle(name: &str) -> Option<Runtime> {
    let dir = format!("{}/artifacts/{name}", env!("CARGO_MANIFEST_DIR"));
    std::path::Path::new(&dir)
        .exists()
        .then(|| Runtime::load(&dir).unwrap())
}

#[test]
fn pallas_bundle_is_flagged_and_loads() {
    let Some(rt) = bundle("tiny-pallas") else {
        eprintln!("skipping: tiny-pallas artifacts not built");
        return;
    };
    assert!(rt.manifest.use_pallas);
    assert!(rt.manifest.programs.contains_key("step_single"));
    assert!(rt.manifest.programs.contains_key("lowrank_iter"));
    assert!(rt.manifest.programs.contains_key("quantize_q4"));
}

#[test]
fn pallas_bundle_matches_its_goldens() {
    let Some(rt) = bundle("tiny-pallas") else { return };
    let man = &rt.manifest;
    for (name, (inputs, outputs)) in &man.goldens {
        let prog = man.program(name).unwrap();
        let mut args = Vec::new();
        for (file, sig) in inputs.iter().zip(&prog.inputs) {
            let rel = format!("goldens/{file}");
            args.push(match sig.dtype {
                DType::F32 => HostTensor::F32(man.read_f32(&rel).unwrap()),
                DType::I32 => HostTensor::I32(man.read_i32(&rel).unwrap()),
            });
        }
        let got = rt
            .exec(name, &args)
            .unwrap_or_else(|e| panic!("pallas program {name}: {e:#}"));
        for (i, (file, out)) in outputs.iter().zip(&got).enumerate() {
            let want = man.read_f32(&format!("goldens/{file}")).unwrap();
            for (a, b) in out.as_f32().unwrap().iter().zip(&want) {
                assert!(
                    (a - b).abs() < 2e-4 + 5e-4 * b.abs(),
                    "{name} out{i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn pallas_and_jnp_lowerings_agree() {
    // Same model, same init, same batch → the pallas-kernel lowering and
    // the plain-jnp lowering must produce the same loss and gradients.
    let (Some(rt_p), Some(rt_j)) = (bundle("tiny-pallas"), bundle("tiny"))
    else {
        return;
    };
    let man = &rt_j.manifest;
    let params = man.read_f32(&man.init["single"].file).unwrap();
    let n_tok = man.dims.microbatch * man.dims.seq_len;
    let v = man.dims.vocab_size as i32;
    let tokens: Vec<i32> = (0..n_tok).map(|i| (i as i32 * 13 + 1) % v).collect();
    let labels: Vec<i32> = (0..n_tok).map(|i| (i as i32 * 17 + 2) % v).collect();

    let (loss_j, g_j) = rt_j.step_single(&params, &tokens, &labels).unwrap();
    let (loss_p, g_p) = rt_p.step_single(&params, &tokens, &labels).unwrap();
    assert!(
        (loss_j - loss_p).abs() < 1e-4 * (1.0 + loss_j.abs()),
        "loss {loss_j} vs {loss_p}"
    );
    let mut worst = 0.0f32;
    for (a, b) in g_j.iter().zip(&g_p) {
        worst = worst.max((a - b).abs());
        assert!(
            (a - b).abs() < 5e-4 + 2e-3 * b.abs(),
            "grads {a} vs {b} (worst {worst})"
        );
    }
}

#[test]
fn quantize_program_puts_values_on_q4_grid() {
    let Some(rt) = bundle("tiny-pallas") else { return };
    let sig = &rt.manifest.program("quantize_q4").unwrap().inputs[0];
    let n: usize = sig.shape.iter().product();
    let x: Vec<f32> = (0..n).map(|i| ((i % 200) as f32 - 100.0) / 37.0).collect();
    let out = rt
        .exec("quantize_q4", &[HostTensor::F32(x.clone())])
        .unwrap();
    let y = out[0].as_f32().unwrap();
    // int4 symmetric grid: at most 15 distinct values.
    let mut distinct: Vec<i64> = y.iter().map(|v| (v * 1e6) as i64).collect();
    distinct.sort_unstable();
    distinct.dedup();
    assert!(distinct.len() <= 15, "got {} distinct levels", distinct.len());
    // Half-step error bound.
    let amax = x.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    let step = amax / 7.0;
    for (a, b) in x.iter().zip(y) {
        assert!((a - b).abs() <= 0.5 * step + 1e-6);
    }
}
