//! Transport subsystem integration: the TCP multi-process ring is a
//! drop-in for the local mpsc ring (bit-for-bit), the elastic coordinator
//! runs a ≥3-process training round over loopback TCP via real
//! `std::process::Command` spawns of the `dilocox worker` binary, and a
//! seeded worker kill mid-run re-forms the ring with the survivors and
//! still reports a final eval.

use dilocox::comm::ring::build_ring;
use dilocox::transport::elastic::{
    run_elastic, run_local_reference, ElasticConfig, SpawnMode,
};
use dilocox::transport::tcp::form_ring;
use dilocox::transport::{ReduceTopology, RingTransport};
use dilocox::util::rng::Pcg32;
use std::net::TcpListener;
use std::time::Duration;

fn dilocox_bin() -> String {
    env!("CARGO_BIN_EXE_dilocox").to_string()
}

fn random_bufs(c: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::seed_from(seed);
    (0..c)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.0, 1.0);
            v
        })
        .collect()
}

#[test]
fn loopback_tcp_allreduce_matches_local_backend_bit_for_bit() {
    let bufs = random_bufs(3, 1001, 31); // non-divisible chunking
    // Local mpsc backend.
    let local: Vec<Vec<f32>> = {
        let members = build_ring(3);
        std::thread::scope(|scope| {
            let handles: Vec<_> = members
                .into_iter()
                .zip(bufs.clone())
                .map(|(mut m, mut b)| {
                    scope.spawn(move || {
                        m.allreduce_mean(&mut b).unwrap();
                        b
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        })
    };
    // TCP backend over real loopback sockets.
    let listeners: Vec<TcpListener> =
        (0..3).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let members: Vec<(u32, u16)> = listeners
        .iter()
        .enumerate()
        .map(|(i, l)| (i as u32, l.local_addr().unwrap().port()))
        .collect();
    let tcp: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = listeners
            .iter()
            .zip(bufs.clone())
            .enumerate()
            .map(|(i, (listener, mut b))| {
                let members = members.clone();
                scope.spawn(move || {
                    let mut ring = form_ring(
                        i as u32,
                        1,
                        &members,
                        listener,
                        Duration::from_secs(10),
                        Duration::from_secs(10),
                    )
                    .unwrap();
                    ring.allreduce_mean(&mut b).unwrap();
                    b
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // Same collective schedule + same fp order ⇒ exact equality.
    assert_eq!(local, tcp);
    // Payload metering matches the §2.4.1 per-worker ring factor too.
    let payload = 4 * 1001u64;
    let per_worker = dilocox::comm::ring_wire_bytes_per_worker(payload, 3);
    assert!(per_worker > 0);
}

#[test]
fn elastic_three_process_tcp_training_round() {
    // The real deployment shape: the coordinator spawns three `dilocox
    // worker` OS processes via std::process::Command and drives a full
    // multi-round run over loopback TCP.
    let mut cfg = ElasticConfig::quadratic(3, 4, 48);
    cfg.transport.ring_timeout_ms = 2000;
    cfg.wall_timeout_ms = 90_000;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.started, 3);
    assert_eq!(out.survivors, vec![0, 1, 2]);
    assert_eq!(out.epochs, 1, "no churn expected");
    assert!(out.total_wire_bytes > 0);
    assert!(out.final_loss.is_finite());
    // Convergence: the final eval beats the round-1 loss decisively.
    let r1: Vec<f32> = out
        .round_losses
        .iter()
        .filter(|(_, r, _)| *r == 1)
        .map(|(_, _, l)| *l)
        .collect();
    assert_eq!(r1.len(), 3, "all three processes heartbeat round 1");
    let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
    assert!(
        out.final_loss < r1_mean * 0.5,
        "final {} vs round-1 {}",
        out.final_loss,
        r1_mean
    );
}

#[test]
fn elastic_survives_process_kill_at_round_2() {
    // Seeded churn: rank 1 exits at the start of round 2; the survivors
    // report the break, the coordinator re-forms the ring (epoch 2), and
    // the run completes every round with a finite final eval — no panic.
    let mut cfg = ElasticConfig::quadratic(3, 6, 48);
    cfg.transport.ring_timeout_ms = 1500;
    cfg.wall_timeout_ms = 90_000;
    cfg.faults.enabled = true;
    cfg.faults.kill_rank = 1;
    cfg.faults.kill_round = 2;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 2], "rank 1 must be gone");
    assert!(out.epochs >= 2, "ring must have re-formed, epochs={}", out.epochs);
    assert!(out.final_loss.is_finite());
    // Survivors completed the full schedule after recovery.
    let max_round = out.round_losses.iter().map(|(_, r, _)| *r).max().unwrap();
    assert_eq!(max_round as usize, cfg.rounds);
    // The survivor ring still converges (mean rescaled to 2 members).
    let r1: Vec<f32> = out
        .round_losses
        .iter()
        .filter(|(_, r, _)| *r == 1)
        .map(|(_, _, l)| *l)
        .collect();
    let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
    assert!(
        out.final_loss < r1_mean * 0.5,
        "final {} vs round-1 {}",
        out.final_loss,
        r1_mean
    );
}

#[test]
fn elastic_rejects_zero_workers() {
    let cfg = ElasticConfig::quadratic(0, 1, 8);
    assert!(run_elastic(&cfg, &SpawnMode::Thread).is_err());
}

#[test]
fn tcp_overlap_fleet_matches_local_reference_bit_for_bit() {
    // One-step-delay overlap across OS processes: the loopback-TCP fleet
    // must be bit-for-bit identical to the in-process reference (same
    // trainers, same epoch-aware driver, local mpsc ring) — final params,
    // mean final loss, AND the wire ledger.
    let mut cfg = ElasticConfig::quadratic(3, 4, 48);
    cfg.overlap = true;
    cfg.transport.ring_timeout_ms = 2000;
    cfg.wall_timeout_ms = 90_000;
    let (ref_params, ref_loss, ref_wire) = run_local_reference(&cfg).unwrap();
    let fleet =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(fleet.epochs, 1, "no churn expected");
    assert_eq!(fleet.survivors, vec![0, 1, 2]);
    assert_eq!(ref_params, fleet.final_params);
    assert_eq!(ref_loss, fleet.final_loss);
    assert_eq!(ref_wire, fleet.total_wire_bytes);
    assert!(fleet.total_wire_bytes > 0);
    // The ledger proves the overlap really overlapped over TCP: round-1
    // heartbeats completed no reduction.
    assert!(fleet
        .round_wire
        .iter()
        .filter(|(_, r, _)| *r == 1)
        .all(|(_, _, b)| *b == 0));
    assert!(fleet
        .round_wire
        .iter()
        .filter(|(_, r, _)| *r == 2)
        .all(|(_, _, b)| *b > 0));
}

#[test]
fn elastic_overlap_process_kill_drains_in_flight_and_completes() {
    // Kill a worker process mid-run under overlap: the survivors both
    // stall joining the same in-flight round, the coordinator commits a
    // DRAIN, the re-formed ring finishes that reduction with
    // survivor-rescaled means, and every round completes with a final
    // eval.
    let mut cfg = ElasticConfig::quadratic(3, 6, 48);
    cfg.overlap = true;
    cfg.transport.ring_timeout_ms = 1500;
    cfg.wall_timeout_ms = 90_000;
    cfg.faults.enabled = true;
    cfg.faults.kill_rank = 1;
    cfg.faults.kill_round = 2;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 2], "rank 1 must be gone");
    assert!(out.epochs >= 2, "epochs={}", out.epochs);
    assert!(
        out.recoveries.iter().any(|&(_, _, d)| d > 0),
        "expected a drain commit, got {:?}",
        out.recoveries
    );
    assert!(out.final_loss.is_finite());
    let max_round = out.round_losses.iter().map(|(_, r, _)| *r).max().unwrap();
    assert_eq!(max_round as usize, cfg.rounds);
}

#[test]
fn tcp_overlap_fleet_with_pool_matches_local_reference_bit_for_bit() {
    // The perf knobs must be invisible to the numerics: with the
    // persistent comm pool and the reduce pipeline enabled (flowing to
    // the worker processes via --comm-pool/--pipeline-depth), the fleet
    // still matches the in-process reference exactly — params, loss, and
    // the wire ledger.
    let mut cfg = ElasticConfig::quadratic(3, 4, 48);
    cfg.overlap = true;
    cfg.transport.ring_timeout_ms = 2000;
    cfg.wall_timeout_ms = 90_000;
    cfg.transport.comm_pool_size = 2;
    cfg.transport.pipeline_depth = 2;
    let (ref_params, ref_loss, ref_wire) = run_local_reference(&cfg).unwrap();
    let fleet =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(fleet.epochs, 1, "no churn expected");
    assert_eq!(fleet.survivors, vec![0, 1, 2]);
    assert_eq!(ref_params, fleet.final_params);
    assert_eq!(ref_loss, fleet.final_loss);
    assert_eq!(ref_wire, fleet.total_wire_bytes);
    assert!(fleet.total_wire_bytes > 0);
}

#[test]
fn elastic_overlap_process_kill_drains_with_pool_and_pipeline() {
    // The drain branch of churn recovery across real OS processes with
    // the comm pool and pipelined reduce enabled: a parked pool thread in
    // the dying worker dies with its process; the survivors' pooled
    // flights are joined by reseed and the re-formed ring drains the
    // in-flight round.
    let mut cfg = ElasticConfig::quadratic(3, 6, 48);
    cfg.overlap = true;
    cfg.transport.ring_timeout_ms = 1500;
    cfg.wall_timeout_ms = 90_000;
    cfg.transport.comm_pool_size = 2;
    cfg.transport.pipeline_depth = 2;
    cfg.faults.enabled = true;
    cfg.faults.kill_rank = 1;
    cfg.faults.kill_round = 2;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 2], "rank 1 must be gone");
    assert!(out.epochs >= 2, "epochs={}", out.epochs);
    assert!(
        out.recoveries.iter().any(|&(_, _, d)| d > 0),
        "expected a drain commit, got {:?}",
        out.recoveries
    );
    assert!(out.final_loss.is_finite());
    let max_round = out.round_losses.iter().map(|(_, r, _)| *r).max().unwrap();
    assert_eq!(max_round as usize, cfg.rounds);
}

fn hier_process_cfg(rounds: usize) -> ElasticConfig {
    let mut cfg = ElasticConfig::quadratic(4, rounds, 48);
    cfg.reduce_topology = ReduceTopology::Hier;
    cfg.sites = vec![0, 0, 1, 1];
    cfg.transport.ring_timeout_ms = 1500;
    cfg.wall_timeout_ms = 90_000;
    cfg
}

#[test]
fn hier_process_fleet_matches_local_reference_bit_for_bit() {
    // The two-level reduce across real worker OS processes (2 sites × 2
    // clusters, intra rings + a leaders-only cross ring) must be
    // bit-for-bit the in-process hier reference: the hier float schedule
    // is a pure function of (site, rank) order, never of the transport.
    let mut cfg = hier_process_cfg(4);
    cfg.transport.ring_timeout_ms = 2000;
    let (ref_params, ref_loss, ref_wire) = run_local_reference(&cfg).unwrap();
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.epochs, 1, "no churn expected");
    assert_eq!(out.survivors, vec![0, 1, 2, 3]);
    assert_eq!(out.final_params, ref_params, "hier process != hier mpsc");
    assert_eq!(out.final_loss, ref_loss);
    assert_eq!(out.total_wire_bytes, ref_wire, "wire ledger diverged");
}

#[test]
fn hier_process_leader_kill_drains_and_completes() {
    // Kill the site-1 leader process (rank 2) mid-run under overlap: the
    // survivors re-form, leadership of site 1 falls to rank 3 purely by
    // position in the committed order, and the drain branch finishes the
    // in-flight reduction across the re-formed two-level rings.
    let mut cfg = hier_process_cfg(6);
    cfg.overlap = true;
    cfg.faults.enabled = true;
    cfg.faults.kill_rank = 2;
    cfg.faults.kill_round = 2;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 1, 3], "site-1 leader must be gone");
    assert!(out.epochs >= 2, "epochs={}", out.epochs);
    assert!(
        out.recoveries.iter().any(|&(_, _, d)| d > 0),
        "expected a drain commit, got {:?}",
        out.recoveries
    );
    assert!(out.final_loss.is_finite());
    let max_round = out.round_losses.iter().map(|(_, r, _)| *r).max().unwrap();
    assert_eq!(max_round as usize, cfg.rounds);
}

#[test]
fn hier_process_soft_break_discards_and_completes() {
    // The discard branch under hier across OS processes: rank 1 (a
    // non-leader) soft-breaks without dying, survivors hold mixed
    // in-flight evidence, the coordinator discards, and everyone —
    // breaker included — completes the schedule.
    let mut cfg = hier_process_cfg(6);
    cfg.overlap = true;
    cfg.faults.enabled = true;
    cfg.faults.break_rank = 1;
    cfg.faults.break_round = 3;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 1, 2, 3], "nobody died");
    assert!(out.epochs >= 2, "epochs={}", out.epochs);
    assert!(
        out.recoveries.iter().all(|&(_, _, d)| d == 0),
        "mixed in-flight must discard, got {:?}",
        out.recoveries
    );
    assert!(out.final_loss.is_finite());
    let max_round = out.round_losses.iter().map(|(_, r, _)| *r).max().unwrap();
    assert_eq!(max_round as usize, cfg.rounds);
}

#[test]
fn elastic_overlap_process_soft_break_discards_with_pool_and_pipeline() {
    // The discard branch with the same knobs on: the breaker parks
    // without dying (its pooled flight is stale), survivors hold mixed
    // in-flight rounds, and the coordinator must discard — everyone,
    // breaker included, completes the schedule.
    let mut cfg = ElasticConfig::quadratic(3, 6, 48);
    cfg.overlap = true;
    cfg.transport.ring_timeout_ms = 1500;
    cfg.wall_timeout_ms = 90_000;
    cfg.transport.comm_pool_size = 2;
    cfg.transport.pipeline_depth = 2;
    cfg.faults.enabled = true;
    cfg.faults.break_rank = 1;
    cfg.faults.break_round = 3;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 1, 2], "nobody died");
    assert!(out.epochs >= 2, "epochs={}", out.epochs);
    assert!(
        out.recoveries.iter().all(|&(_, _, d)| d == 0),
        "mixed in-flight must discard, got {:?}",
        out.recoveries
    );
    assert!(out.final_loss.is_finite());
    let max_round = out.round_losses.iter().map(|(_, r, _)| *r).max().unwrap();
    assert_eq!(max_round as usize, cfg.rounds);
}
