//! Observability integration: tracing is bit-for-bit inert on the elastic
//! fleet, span sums account for measured wall time, both churn-recovery
//! branches leave `recovery.*` evidence in the merged timeline, and the
//! real (not hand-built) timelines pass the `trace-check` validator.
//!
//! Every test here mutates the process-wide [`dilocox::obs`] switches, so
//! they serialize on one lock and restore the disabled state on the way
//! out — the rest of this binary's tests never see tracing enabled.

use dilocox::obs;
use dilocox::obs::report::{
    chrome_trace_events, round_accounting, validate_chrome_trace,
};
use dilocox::obs::TraceEvent;
use dilocox::transport::elastic::{
    run_elastic, ElasticConfig, ElasticOutcome, SpawnMode,
};
use dilocox::util::json::obj;
use std::collections::BTreeSet;
use std::sync::Mutex;
use std::time::Instant;

static OBS_LOCK: Mutex<()> = Mutex::new(());

fn quick_cfg(workers: usize, rounds: usize, dim: usize) -> ElasticConfig {
    let mut cfg = ElasticConfig::quadratic(workers, rounds, dim);
    cfg.transport.ring_timeout_ms = 1000;
    cfg.transport.connect_timeout_ms = 5000;
    cfg.wall_timeout_ms = 60_000;
    cfg
}

/// Order-independent view of the per-round heartbeat telemetry (worker
/// arrival order at the coordinator is nondeterministic).
fn loss_set(out: &ElasticOutcome) -> BTreeSet<(u32, u32, u32)> {
    out.round_losses
        .iter()
        .map(|&(w, r, l)| (w, r, l.to_bits()))
        .collect()
}

fn reset_obs() {
    obs::set_enabled(false);
    obs::drain();
}

#[test]
fn traced_run_is_bit_identical_to_untraced() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    reset_obs();
    let mut cfg = quick_cfg(3, 4, 48);
    let off = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
    assert!(off.trace_events.is_empty(), "untraced run must ship no events");

    cfg.trace = true;
    let on = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
    reset_obs();

    // Zero-overhead-when-disabled has a stronger sibling: enabled tracing
    // must not perturb the numerics, the telemetry, or the wire ledger.
    assert_eq!(off.final_params, on.final_params);
    assert_eq!(off.final_loss.to_bits(), on.final_loss.to_bits());
    assert_eq!(off.total_wire_bytes, on.total_wire_bytes);
    assert_eq!(loss_set(&off), loss_set(&on));

    // And the traced run actually produced a validating timeline.
    assert!(!on.trace_events.is_empty());
    let doc = obj(vec![("traceEvents", chrome_trace_events(&on.trace_events))]);
    let n = validate_chrome_trace(&doc, false).unwrap();
    assert_eq!(n, on.trace_events.len());
    // Per-round accounting covers every training round with nonzero
    // compute (round 0 additionally holds the pre-round barrier spans).
    let accounts = round_accounting(&on.trace_events);
    for r in 1..=cfg.rounds as u32 {
        assert!(
            accounts.iter().any(|a| a.round == r),
            "round {r} missing from accounting"
        );
    }
}

#[test]
fn span_sums_account_for_wall_time() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    reset_obs();
    let mut cfg = quick_cfg(2, 3, 48);
    cfg.trace = true;
    let t0 = Instant::now();
    let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
    let wall_us = t0.elapsed().as_micros() as u64;
    reset_obs();

    // Round spans on one (cluster, stage, tid) track are sequential in
    // real time, so their durations can never sum past the measured wall
    // clock (generous slack for the µs truncation at both ends).
    let mut tracks: BTreeSet<(u32, u32, u32)> = BTreeSet::new();
    for e in &out.trace_events {
        if e.phase == "round" {
            tracks.insert((e.cluster, e.stage, e.tid));
        }
    }
    assert!(!tracks.is_empty(), "no round spans recorded");
    for (cluster, stage, tid) in tracks {
        let on_track = |e: &&TraceEvent| {
            e.cluster == cluster && e.stage == stage && e.tid == tid
        };
        let rounds: Vec<&TraceEvent> = out
            .trace_events
            .iter()
            .filter(on_track)
            .filter(|e| e.phase == "round")
            .collect();
        assert_eq!(rounds.len(), cfg.rounds, "one round span per round");
        let round_sum: u64 = rounds.iter().map(|e| e.dur_us).sum();
        assert!(
            round_sum <= wall_us + 100_000,
            "round spans ({round_sum} us) exceed wall ({wall_us} us)"
        );
        // Every compute span nests inside its round span, so per-round
        // child sums are bounded by the parent duration.
        for r in &rounds {
            let child_sum: u64 = out
                .trace_events
                .iter()
                .filter(on_track)
                .filter(|e| {
                    e.phase != "round"
                        && e.start_us >= r.start_us
                        && e.start_us + e.dur_us <= r.start_us + r.dur_us
                })
                .filter(|e| e.phase == "compute" || e.phase == "consensus")
                .map(|e| e.dur_us)
                .sum();
            assert!(
                child_sum <= r.dur_us,
                "children ({child_sum} us) exceed round span ({} us)",
                r.dur_us
            );
        }
    }
    // The fleet did measurable compute somewhere.
    let compute_us: u64 = out
        .trace_events
        .iter()
        .filter(|e| e.phase == "compute")
        .map(|e| e.dur_us)
        .sum();
    let computes = out
        .trace_events
        .iter()
        .filter(|e| e.phase == "compute")
        .count();
    assert_eq!(computes, cfg.workers * cfg.rounds, "one compute span per (worker, round)");
    assert!(compute_us <= wall_us + 100_000);
}

#[test]
fn kill_under_overlap_records_drain_recovery_spans() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    reset_obs();
    let mut cfg = quick_cfg(3, 6, 32);
    cfg.overlap = true;
    cfg.trace = true;
    cfg.faults.enabled = true;
    cfg.faults.kill_rank = 1;
    cfg.faults.kill_round = 2;
    let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
    reset_obs();

    assert_eq!(out.survivors, vec![0, 2]);
    assert!(
        out.recoveries.iter().any(|&(_, _, d)| d > 0),
        "expected a drain commit, got {:?}",
        out.recoveries
    );
    assert!(
        out.trace_events.iter().any(|e| e.phase == "recovery.drain"),
        "drain branch must leave a recovery.drain span"
    );
    // The coordinator's own 2PC spans made it into the merged timeline.
    assert!(out
        .trace_events
        .iter()
        .any(|e| e.cluster == obs::COORD && e.phase == "epoch.commit"));
    // A churn timeline passes the validator WITH the recovery demand.
    let doc = obj(vec![("traceEvents", chrome_trace_events(&out.trace_events))]);
    validate_chrome_trace(&doc, true).unwrap();
}

#[test]
fn soft_break_under_overlap_records_discard_spans() {
    let _g = OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    reset_obs();
    let mut cfg = quick_cfg(3, 6, 32);
    cfg.overlap = true;
    cfg.trace = true;
    cfg.faults.enabled = true;
    cfg.faults.break_rank = 1;
    cfg.faults.break_round = 3;
    let out = run_elastic(&cfg, &SpawnMode::Thread).unwrap();
    reset_obs();

    // A soft break keeps all members; recovery commits are discards.
    assert_eq!(out.survivors, vec![0, 1, 2]);
    assert!(out.recoveries.iter().all(|&(_, _, d)| d == 0));
    assert!(
        out.trace_events.iter().any(|e| e.phase == "recovery.discard"),
        "discard branch must leave a recovery.discard span"
    );
    let doc = obj(vec![("traceEvents", chrome_trace_events(&out.trace_events))]);
    validate_chrome_trace(&doc, true).unwrap();
}
