//! Pipeline-parallel composition from rust: chaining the per-stage HLO
//! programs (fwd_first → fwd_mid* → fwd_last, then the backward chain)
//! must reproduce the monolithic step_single program — the §2.2 partition
//! run through the real runtime, driven by the 1F1B schedule.
//!
//! The second half exercises the *stage-parallel executor* (PR 2): the
//! artifact-free synthetic multi-stage workload runs unconditionally; the
//! artifact-gated test checks a microbatched stage-parallel training run
//! against a monolithic reference computed with `step_single`.

use dilocox::model::{stage_ranges, ParamStore};
use dilocox::pipeline;
use dilocox::runtime::{HostTensor, Runtime};

fn tiny() -> Option<Runtime> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");
    std::path::Path::new(dir)
        .exists()
        .then(|| Runtime::load(dir).unwrap())
}

fn batch(man: &dilocox::runtime::Manifest) -> (Vec<i32>, Vec<i32>) {
    let n = man.dims.microbatch * man.dims.seq_len;
    let v = man.dims.vocab_size as i32;
    let tokens: Vec<i32> = (0..n).map(|i| (i as i32 * 7 + 3) % v).collect();
    let labels: Vec<i32> = (0..n).map(|i| (i as i32 * 11 + 5) % v).collect();
    (tokens, labels)
}

#[test]
fn stage_chain_forward_matches_single() {
    let Some(rt) = tiny() else { return };
    let man = &rt.manifest;
    let (tokens, labels) = batch(man);

    let single = ParamStore::from_manifest(man, "single").unwrap();
    let loss_single = rt
        .eval_single(&single.flat, &tokens, &labels)
        .unwrap();

    // Forward chain over stages.
    let kinds = man.stage_kinds();
    let mut acts: Option<Vec<f32>> = None;
    let mut loss_pipe = f32::NAN;
    for (i, kind) in kinds.iter().enumerate() {
        let stage = ParamStore::from_manifest(man, &format!("stage_{i}")).unwrap();
        match *kind {
            "first" => {
                let out = rt
                    .exec(
                        "fwd_first",
                        &[
                            HostTensor::F32(stage.flat.clone()),
                            HostTensor::I32(tokens.clone()),
                        ],
                    )
                    .unwrap();
                acts = Some(out[0].clone().into_f32().unwrap());
            }
            "mid" => {
                let out = rt
                    .exec(
                        "fwd_mid",
                        &[
                            HostTensor::F32(stage.flat.clone()),
                            HostTensor::F32(acts.clone().unwrap()),
                        ],
                    )
                    .unwrap();
                acts = Some(out[0].clone().into_f32().unwrap());
            }
            "last" => {
                let out = rt
                    .exec(
                        "fwd_last",
                        &[
                            HostTensor::F32(stage.flat.clone()),
                            HostTensor::F32(acts.clone().unwrap()),
                            HostTensor::I32(labels.clone()),
                        ],
                    )
                    .unwrap();
                loss_pipe = out[0].scalar_f32().unwrap();
            }
            other => panic!("unexpected stage kind {other}"),
        }
    }
    assert!(
        (loss_pipe - loss_single).abs() < 1e-4 * (1.0 + loss_single.abs()),
        "pipeline fwd {loss_pipe} vs single {loss_single}"
    );
}

#[test]
fn stage_chain_backward_matches_single_grads() {
    let Some(rt) = tiny() else { return };
    let man = &rt.manifest;
    let (tokens, labels) = batch(man);
    let single = ParamStore::from_manifest(man, "single").unwrap();

    let (loss_single, g_single) = rt
        .step_single(&single.flat, &tokens, &labels)
        .unwrap();

    // Forward chain, stashing stage inputs.
    let kinds = man.stage_kinds();
    let stages: Vec<ParamStore> = (0..kinds.len())
        .map(|i| ParamStore::from_manifest(man, &format!("stage_{i}")).unwrap())
        .collect();
    let mut stage_inputs: Vec<Vec<f32>> = Vec::new(); // acts entering stage i (i>=1)
    let mut acts: Vec<f32> = {
        let out = rt
            .exec(
                "fwd_first",
                &[
                    HostTensor::F32(stages[0].flat.clone()),
                    HostTensor::I32(tokens.clone()),
                ],
            )
            .unwrap();
        out[0].clone().into_f32().unwrap()
    };
    for i in 1..kinds.len() - 1 {
        stage_inputs.push(acts.clone());
        let out = rt
            .exec(
                "fwd_mid",
                &[
                    HostTensor::F32(stages[i].flat.clone()),
                    HostTensor::F32(acts.clone()),
                ],
            )
            .unwrap();
        acts = out[0].clone().into_f32().unwrap();
    }
    stage_inputs.push(acts.clone());

    // Backward chain.
    let mut grads: Vec<Vec<f32>> = vec![Vec::new(); kinds.len()];
    let last = kinds.len() - 1;
    let out = rt
        .exec(
            "bwd_last",
            &[
                HostTensor::F32(stages[last].flat.clone()),
                HostTensor::F32(stage_inputs[last - 1].clone()),
                HostTensor::I32(labels.clone()),
            ],
        )
        .unwrap();
    let loss_pipe = out[0].scalar_f32().unwrap();
    grads[last] = out[1].clone().into_f32().unwrap();
    let mut g_acts = out[2].clone().into_f32().unwrap();
    for i in (1..last).rev() {
        let out = rt
            .exec(
                "bwd_mid",
                &[
                    HostTensor::F32(stages[i].flat.clone()),
                    HostTensor::F32(stage_inputs[i - 1].clone()),
                    HostTensor::F32(g_acts.clone()),
                ],
            )
            .unwrap();
        grads[i] = out[0].clone().into_f32().unwrap();
        g_acts = out[1].clone().into_f32().unwrap();
    }
    let out = rt
        .exec(
            "bwd_first",
            &[
                HostTensor::F32(stages[0].flat.clone()),
                HostTensor::I32(tokens.clone()),
                HostTensor::F32(g_acts),
            ],
        )
        .unwrap();
    grads[0] = out[0].clone().into_f32().unwrap();

    assert!(
        (loss_pipe - loss_single).abs() < 1e-4 * (1.0 + loss_single.abs()),
        "{loss_pipe} vs {loss_single}"
    );
    let g_pipe: Vec<f32> = grads.concat();
    assert_eq!(g_pipe.len(), g_single.len());
    // Validate against the manifest's stage ranges too.
    let ranges = stage_ranges(man);
    assert_eq!(ranges.last().unwrap().end, g_pipe.len());
    let mut worst = 0.0f32;
    for (a, b) in g_pipe.iter().zip(&g_single) {
        worst = worst.max((a - b).abs() / (1e-3 + b.abs()));
        assert!(
            (a - b).abs() < 1e-4 + 2e-3 * b.abs(),
            "grad mismatch {a} vs {b} (worst {worst})"
        );
    }
}

#[test]
fn schedule_drives_real_stage_programs() {
    // Execute a 2-microbatch 1F1B schedule with the real HLO programs:
    // gradient accumulation over microbatches must equal the sum of
    // per-microbatch step_single gradients.
    let Some(rt) = tiny() else { return };
    let man = &rt.manifest;
    let m = man.dims.pp_stages;
    let micros = 2usize;
    let streams = pipeline::one_f_one_b_schedule(m, micros);
    pipeline::validate_schedule(&streams, micros).unwrap();

    let single = ParamStore::from_manifest(man, "single").unwrap();
    let (t0, l0) = batch(man);
    // Second microbatch: shifted pattern.
    let v = man.dims.vocab_size as i32;
    let t1: Vec<i32> = t0.iter().map(|x| (x + 1) % v).collect();
    let l1: Vec<i32> = l0.iter().map(|x| (x + 1) % v).collect();

    let (_, g0) = rt.step_single(&single.flat, &t0, &l0).unwrap();
    let (_, g1) = rt.step_single(&single.flat, &t1, &l1).unwrap();
    let want: Vec<f32> = g0.iter().zip(&g1).map(|(a, b)| a + b).collect();

    // Accumulate via stage programs, microbatch by microbatch (the
    // schedule's per-stage order is validated above; numerically the
    // accumulation is order-independent).
    let stages: Vec<ParamStore> = (0..m)
        .map(|i| ParamStore::from_manifest(man, &format!("stage_{i}")).unwrap())
        .collect();
    let mut acc = vec![0.0f32; man.param_count];
    for (tok, lab) in [(&t0, &l0), (&t1, &l1)] {
        // fwd chain
        let mut inputs: Vec<Vec<f32>> = Vec::new();
        let out = rt
            .exec(
                "fwd_first",
                &[
                    HostTensor::F32(stages[0].flat.clone()),
                    HostTensor::I32(tok.clone()),
                ],
            )
            .unwrap();
        let mut acts = out[0].clone().into_f32().unwrap();
        for i in 1..m - 1 {
            inputs.push(acts.clone());
            let out = rt
                .exec(
                    "fwd_mid",
                    &[
                        HostTensor::F32(stages[i].flat.clone()),
                        HostTensor::F32(acts),
                    ],
                )
                .unwrap();
            acts = out[0].clone().into_f32().unwrap();
        }
        inputs.push(acts);
        // bwd chain
        let out = rt
            .exec(
                "bwd_last",
                &[
                    HostTensor::F32(stages[m - 1].flat.clone()),
                    HostTensor::F32(inputs[m - 2].clone()),
                    HostTensor::I32(lab.clone()),
                ],
            )
            .unwrap();
        let mut off_end = man.param_count;
        let ranges = stage_ranges(man);
        let gp = out[1].as_f32().unwrap();
        acc[ranges[m - 1].clone()]
            .iter_mut()
            .zip(gp)
            .for_each(|(a, b)| *a += b);
        let mut g_acts = out[2].clone().into_f32().unwrap();
        for i in (1..m - 1).rev() {
            let out = rt
                .exec(
                    "bwd_mid",
                    &[
                        HostTensor::F32(stages[i].flat.clone()),
                        HostTensor::F32(inputs[i - 1].clone()),
                        HostTensor::F32(g_acts),
                    ],
                )
                .unwrap();
            acc[ranges[i].clone()]
                .iter_mut()
                .zip(out[0].as_f32().unwrap())
                .for_each(|(a, b)| *a += b);
            g_acts = out[1].clone().into_f32().unwrap();
        }
        let out = rt
            .exec(
                "bwd_first",
                &[
                    HostTensor::F32(stages[0].flat.clone()),
                    HostTensor::I32(tok.clone()),
                    HostTensor::F32(g_acts),
                ],
            )
            .unwrap();
        acc[ranges[0].clone()]
            .iter_mut()
            .zip(out[0].as_f32().unwrap())
            .for_each(|(a, b)| *a += b);
        off_end -= 0; // silence unused warnings pattern
        let _ = off_end;
    }

    for (a, b) in acc.iter().zip(&want) {
        assert!(
            (a - b).abs() < 2e-4 + 2e-3 * b.abs(),
            "microbatch accumulation {a} vs {b}"
        );
    }
}

// ---------------------------------------------------------------------------
// Stage-parallel 1F1B executor (threads + channels + per-stage rings)
// ---------------------------------------------------------------------------

/// Artifact-free: the real executor on the synthetic multi-stage
/// workload — 3 DP workers × 4 stage threads, 6 in-flight microbatches,
/// int8 per-stage rings, error feedback, one-step-delay overlap.  Runs
/// (never skips) and must converge decisively.
#[test]
fn synthetic_multi_stage_executor_converges_without_artifacts() {
    use dilocox::compress::Method;
    use dilocox::pipeline::exec::{
        local_stage_rings, run_pipeline, PipelineRunOpts, SyntheticPipeline,
    };

    let wl = SyntheticPipeline::new(4, 6, 24, 2024);
    let opts = PipelineRunOpts {
        rounds: 5,
        local_steps: 8,
        inner_lr: 0.05,
        weight_decay: 0.0,
        // Gentle outer gains: delayed outer updates oscillate on the
        // fast-converging chain at the paper's transformer settings.
        outer_lr: 0.3,
        outer_momentum: 0.3,
        overlap: true,
        error_feedback: true,
        method: Method::Quant { q_bits: 8 },
        seed: 2024,
        comm_pool_size: 1,
        pipeline_depth: 1,
    };
    let out = run_pipeline(&wl, 3, local_stage_rings(3, 4), &opts).unwrap();
    assert_eq!(out.final_params.len(), 4 * 24);
    assert!(out.total_wire_bytes > 0);
    let first = out.mean_loss_per_round().first().unwrap().1;
    assert!(
        out.final_eval < first * 0.5,
        "final {} vs round-1 {first}",
        out.final_eval
    );
}

/// Artifact-gated: a microbatched (U = 2) stage-parallel run through the
/// public coordinator API must match a monolithic reference that draws
/// the same shard stream and averages `step_single` gradients over the
/// same microbatches — the executed pipeline is the partitioned model,
/// not an approximation of it.
#[test]
fn stage_parallel_microbatched_matches_monolithic_reference() {
    use dilocox::config::{Algo, ExperimentConfig};
    use dilocox::coordinator::run_threaded;
    use dilocox::data::{MarkovCorpus, ShardIter};
    use dilocox::optim::{AdamW, Nesterov};
    use std::sync::Arc;

    let Some(rt) = tiny() else { return };
    let man = &rt.manifest;
    let micros = 2usize;
    let (dp, rounds, h) = (2usize, 2usize, 2usize);

    let mut cfg = ExperimentConfig::default_for("tiny", Algo::DiLoCoX);
    cfg.parallel.dp = dp;
    cfg.parallel.pp = man.dims.pp_stages;
    cfg.parallel.microbatches = micros;
    cfg.train.outer_steps = rounds;
    cfg.train.local_steps = h;
    cfg.train.inner_lr = 3e-3;
    cfg.train.outer_lr = 0.5;
    cfg.train.overlap = false;
    cfg.compression.enabled = false; // fp32 ring: exact per-element sums
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");
    let staged = run_threaded(&cfg, dir).unwrap();

    // Monolithic reference: same data, same optimizer algebra, same
    // microbatch gradient mean — through step_single.
    let n = man.param_count;
    let theta0 = man.read_f32(&man.init["single"].file).unwrap();
    let (b, s) = (man.dims.microbatch, man.dims.seq_len);
    let corpus = Arc::new(MarkovCorpus::new(man.dims.vocab_size, cfg.train.seed));
    let mut shards: Vec<ShardIter> = (0..dp)
        .map(|w| ShardIter::new(Arc::clone(&corpus), w, cfg.train.seed, b, s))
        .collect();
    let mut params: Vec<Vec<f32>> = vec![theta0.clone(); dp];
    let mut inner: Vec<AdamW> = (0..dp)
        .map(|_| AdamW::new(n, cfg.train.inner_lr, cfg.train.weight_decay))
        .collect();
    let mut theta_g = theta0;
    let mut outer = Nesterov::new(n, cfg.train.outer_lr, cfg.train.outer_momentum);
    for _round in 0..rounds {
        let anchors = params.clone();
        for w in 0..dp {
            for _step in 0..h {
                let mut grad_acc = vec![0.0f32; n];
                for _m in 0..micros {
                    let (tok, lab) = shards[w].next_batch();
                    let (_, g) = rt.step_single(&params[w], &tok, &lab).unwrap();
                    for (a, gi) in grad_acc.iter_mut().zip(&g) {
                        *a += gi;
                    }
                }
                let inv = 1.0 / micros as f32;
                grad_acc.iter_mut().for_each(|x| *x *= inv);
                inner[w].step(&mut params[w], &grad_acc);
            }
        }
        let mut delta = vec![0.0f32; n];
        for w in 0..dp {
            for i in 0..n {
                delta[i] += (anchors[w][i] - params[w][i]) / dp as f32;
            }
        }
        outer.step(&mut theta_g, &delta);
        for p in params.iter_mut() {
            p.copy_from_slice(&theta_g);
        }
    }

    assert_eq!(staged.final_params.len(), theta_g.len());
    let mut max_dev = 0.0f32;
    let mut sum_dev = 0.0f64;
    for (a, b) in staged.final_params.iter().zip(&theta_g) {
        let d = (a - b).abs();
        max_dev = max_dev.max(d);
        sum_dev += d as f64;
    }
    let mean_dev = sum_dev / theta_g.len() as f64;
    // Stage-chained grads differ from the monolithic program only by fp
    // reassociation; AdamW can amplify a near-zero sign flip to ~lr per
    // element, so bound the mean tightly and the max loosely.
    assert!(mean_dev < 2e-3, "mean param dev {mean_dev}");
    assert!(max_dev < 5e-2, "max param dev {max_dev}");
}
