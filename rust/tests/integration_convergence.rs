//! End-to-end algorithmic behaviour on the tiny preset: the orderings the
//! paper's Fig. 3 / Table 1 report must already be visible at unit scale,
//! and the threaded coordinator must agree with the reference trainer.
//!
//! Time comparisons use the *modeled* WAN overhead (elapsed − compute),
//! never raw wall clock: cargo runs tests concurrently and wall time on a
//! shared core is meaningless.

use dilocox::config::{Algo, ExperimentConfig};
use dilocox::train::{run_experiment, run_with_runtime, RunOpts};

fn tiny_dir() -> Option<String> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny");
    std::path::Path::new(dir).exists().then(|| dir.to_string())
}

fn cfg(algo: Algo, outer: usize, h: usize) -> ExperimentConfig {
    let mut c = ExperimentConfig::default_for("tiny", algo);
    c.train.outer_steps = outer;
    c.train.local_steps = h;
    c.train.inner_lr = 3e-3;
    c.train.outer_lr = 0.5;
    c.compression.rank = 8;
    c.compression.adaptive = false;
    c
}

fn opts() -> RunOpts {
    RunOpts { eval_batches: 3, quiet: true, ..Default::default() }
}

#[test]
fn dilocox_tracks_allreduce_with_same_step_budget() {
    // Shape of Fig 3: DiLoCoX's final loss stays in AllReduce's
    // neighbourhood at the same total inner-step budget (the paper's gap
    // at 4000 steps is ~0.2; at 24 steps the band is necessarily wider).
    let Some(dir) = tiny_dir() else { return };
    let rt = dilocox::runtime::Runtime::load(&dir).unwrap();

    let mut ar = cfg(Algo::AllReduce, 6, 4); // 24 sync steps
    ar.artifacts_dir = dir.clone();
    let out_ar = run_with_runtime(&ar, &opts(), &rt).unwrap();

    let mut dx = cfg(Algo::DiLoCoX, 6, 4); // 24 local steps
    dx.artifacts_dir = dir.clone();
    let out_dx = run_with_runtime(&dx, &opts(), &rt).unwrap();

    let l_ar = out_ar.metrics.final_eval_loss.unwrap();
    let l_dx = out_dx.metrics.final_eval_loss.unwrap();
    assert!(l_ar < 5.6, "allreduce should learn: {l_ar}");
    assert!(l_dx < 5.6, "dilocox should learn: {l_dx}");
    assert!(
        l_dx < l_ar + 1.0,
        "dilocox {l_dx} should track allreduce {l_ar}"
    );

    // Communication: DiLoCoX must move far fewer bytes.
    let b_ar = out_ar.metrics.total_wire_bytes();
    let b_dx = out_dx.metrics.total_wire_bytes();
    assert!(
        (b_ar as f64) / (b_dx as f64) > 10.0,
        "wire reduction {b_ar} vs {b_dx}"
    );
}

#[test]
fn ablation_ordering_matches_table1_shape() {
    // Table 1 shape via the modeled WAN overhead per run: overlap hides
    // the sync, compression shrinks it, uncompressed sync is slowest.
    let Some(dir) = tiny_dir() else { return };
    let rt = dilocox::runtime::Runtime::load(&dir).unwrap();
    let o = opts();

    let overhead = |m: &dilocox::metrics::RunMetrics| -> f64 {
        m.records
            .iter()
            .map(|r| (r.elapsed_secs - r.compute_secs).max(0.0))
            .sum()
    };
    let comm_total = |m: &dilocox::metrics::RunMetrics| -> f64 {
        m.records.iter().map(|r| r.comm_secs).sum()
    };

    let mut full = cfg(Algo::DiLoCoX, 6, 4);
    full.artifacts_dir = dir.clone();
    let r_full = run_with_runtime(&full, &o, &rt).unwrap();

    let mut no_ov = cfg(Algo::DiLoCoX, 6, 4);
    no_ov.train.overlap = false;
    no_ov.artifacts_dir = dir.clone();
    let r_noov = run_with_runtime(&no_ov, &o, &rt).unwrap();

    let mut no_cmp = cfg(Algo::DiLoCoX, 6, 4);
    no_cmp.compression.enabled = false;
    no_cmp.train.overlap = false;
    no_cmp.artifacts_dir = dir.clone();
    let r_nocmp = run_with_runtime(&no_cmp, &o, &rt).unwrap();

    let (l_full, l_noov, l_nocmp) = (
        r_full.metrics.final_eval_loss.unwrap(),
        r_noov.metrics.final_eval_loss.unwrap(),
        r_nocmp.metrics.final_eval_loss.unwrap(),
    );
    assert!(l_full < 5.6 && l_noov < 5.6 && l_nocmp < 5.6,
            "{l_full} {l_noov} {l_nocmp}");
    // Removing compression must not hurt convergence.
    assert!(l_nocmp < l_noov + 0.3, "no-comp {l_nocmp} vs no-ov {l_noov}");

    // WAN overhead shape (Table 1's throughput column mechanism):
    let (o_full, o_noov, o_nocmp) = (
        overhead(&r_full.metrics),
        overhead(&r_noov.metrics),
        overhead(&r_nocmp.metrics),
    );
    assert!(
        o_full <= o_noov + 1e-9,
        "overlap must not add overhead: {o_full} vs {o_noov}"
    );
    assert!(
        o_noov < o_nocmp,
        "compression must cut sync time: {o_noov} vs {o_nocmp}"
    );
    // Modeled comm never favours the uncompressed sync...
    assert!(comm_total(&r_noov.metrics) <= comm_total(&r_nocmp.metrics) + 1e-9);
    // ...and the wire itself is >5x smaller (at tiny scale the 30 ms WAN
    // latency dominates comm *time*, so bytes are the right lever here).
    let bytes_noov = r_noov.metrics.total_wire_bytes();
    let bytes_nocmp = r_nocmp.metrics.total_wire_bytes();
    assert!(
        bytes_noov * 5 < bytes_nocmp,
        "wire {bytes_noov} vs {bytes_nocmp}"
    );
}

#[test]
fn threaded_coordinator_agrees_with_reference_trainer() {
    // Same config, same seeds: the threaded ring implementation and the
    // single-process reference must land on nearby parameters and the
    // same eval loss.  (Bit-exactness is impossible: ring-sum order and
    // int4 grid snapping near rounding boundaries differ.)
    let Some(dir) = tiny_dir() else { return };
    let mut c = cfg(Algo::DiLoCoX, 3, 4);
    c.train.overlap = false; // deterministic joint schedule
    c.artifacts_dir = dir.clone();

    let reference = run_experiment(&c, &opts()).unwrap();
    let threaded = dilocox::coordinator::run_threaded(&c, &dir).unwrap();

    assert_eq!(reference.params.len(), threaded.final_params.len());
    let mut worst = 0.0f32;
    for (a, b) in reference.params.iter().zip(&threaded.final_params) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 0.05, "reference vs threaded max dev {worst}");
    let l_ref = reference.metrics.final_eval_loss.unwrap();
    assert!(
        (l_ref - threaded.final_eval).abs() < 0.1,
        "eval {l_ref} vs {}",
        threaded.final_eval
    );
}

#[test]
fn error_feedback_rescues_aggressive_compression() {
    // Algorithm 2's e_t term: under aggressive rank-2 compression, error
    // feedback must not be worse than dropping the residual, and the
    // residual itself must be nonzero (compression is really lossy).
    let Some(dir) = tiny_dir() else { return };
    let rt = dilocox::runtime::Runtime::load(&dir).unwrap();
    let o = opts();

    let mut with_ef = cfg(Algo::DiLoCoX, 8, 3);
    with_ef.compression.rank = 2;
    with_ef.train.overlap = false;
    with_ef.artifacts_dir = dir.clone();
    let r_ef = run_with_runtime(&with_ef, &o, &rt).unwrap();

    let mut no_ef = cfg(Algo::DiLoCoX, 8, 3);
    no_ef.compression.rank = 2;
    no_ef.train.overlap = false;
    no_ef.compression.error_feedback = false;
    no_ef.artifacts_dir = dir.clone();
    let r_noef = run_with_runtime(&no_ef, &o, &rt).unwrap();

    let l_ef = r_ef.metrics.final_eval_loss.unwrap();
    let l_noef = r_noef.metrics.final_eval_loss.unwrap();
    assert!(l_ef < 5.6, "EF run should learn: {l_ef}");
    assert!(
        l_ef <= l_noef + 0.15,
        "error feedback should not hurt: {l_ef} vs {l_noef}"
    );
    // Compression at rank 2 is genuinely lossy (ratio >> 10x).
    let rec = r_ef.metrics.records.last().unwrap();
    assert!(rec.compression_ratio > 10.0, "{}", rec.compression_ratio);
}
