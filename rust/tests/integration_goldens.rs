//! Cross-language numerics: execute every goldened HLO program through the
//! rust PJRT runtime on the inputs python saved, and compare against the
//! outputs live jax produced.  This is the load-bearing L2↔L3 contract
//! test: layout, dtype, tuple order, and numerics all have to line up.

use dilocox::runtime::{DType, HostTensor, Runtime};

fn bundle(name: &str) -> Option<Runtime> {
    let dir = format!("{}/artifacts/{name}", env!("CARGO_MANIFEST_DIR"));
    std::path::Path::new(&dir)
        .exists()
        .then(|| Runtime::load(&dir).unwrap())
}

fn check_goldens(rt: &Runtime, rtol: f32, atol: f32) {
    let man = &rt.manifest;
    assert!(!man.goldens.is_empty(), "bundle has no goldens");
    for (name, (inputs, outputs)) in &man.goldens {
        let prog = man.program(name).unwrap();
        let mut args = Vec::new();
        for (file, sig) in inputs.iter().zip(&prog.inputs) {
            let rel = format!("goldens/{file}");
            let t = match sig.dtype {
                DType::F32 => HostTensor::F32(man.read_f32(&rel).unwrap()),
                DType::I32 => HostTensor::I32(man.read_i32(&rel).unwrap()),
            };
            args.push(t);
        }
        let got = rt.exec(name, &args).unwrap_or_else(|e| {
            panic!("executing golden program {name}: {e:#}")
        });
        assert_eq!(got.len(), outputs.len(), "{name}: output arity");
        for (i, (file, out)) in outputs.iter().zip(&got).enumerate() {
            let want = man.read_f32(&format!("goldens/{file}")).unwrap();
            let gotv = out.as_f32().unwrap();
            assert_eq!(gotv.len(), want.len(), "{name} out{i} len");
            let mut worst = 0.0f32;
            for (a, b) in gotv.iter().zip(&want) {
                let dev = (a - b).abs() / (1.0 + b.abs());
                worst = worst.max(dev);
                assert!(
                    (a - b).abs() <= atol + rtol * b.abs().max(1.0),
                    "{name} out{i}: {a} vs {b} (worst rel dev {worst})"
                );
            }
        }
    }
}

#[test]
fn tiny_bundle_matches_jax_goldens() {
    let Some(rt) = bundle("tiny") else {
        eprintln!("skipping: tiny artifacts not built");
        return;
    };
    check_goldens(&rt, 2e-4, 2e-5);
}

#[test]
fn small_bundle_matches_jax_goldens() {
    let Some(rt) = bundle("small") else {
        eprintln!("skipping: small artifacts not built");
        return;
    };
    check_goldens(&rt, 5e-4, 5e-5);
}

#[test]
fn host_adamw_matches_hlo_adamw() {
    // The trainer's host-side AdamW must be bit-compatible (to fp32
    // accumulation tolerance) with the exported adamw_single program.
    let Some(rt) = bundle("tiny") else { return };
    let man = &rt.manifest;
    let n = man.param_count;
    let p0 = man.read_f32(&man.init["single"].file).unwrap();
    let mut rngstate = 0x12345u64;
    let mut grads = vec![0.0f32; n];
    for g in grads.iter_mut() {
        // xorshift for a cheap deterministic pattern
        rngstate ^= rngstate << 13;
        rngstate ^= rngstate >> 7;
        rngstate ^= rngstate << 17;
        *g = ((rngstate % 2000) as f32 / 1000.0 - 1.0) * 1e-2;
    }
    let (lr, wd, t) = (1e-3f32, 0.01f32, 1.0f32);

    let out = rt
        .exec(
            "adamw_single",
            &[
                HostTensor::F32(p0.clone()),
                HostTensor::F32(grads.clone()),
                HostTensor::F32(vec![0.0; n]),
                HostTensor::F32(vec![0.0; n]),
                HostTensor::F32(vec![t]),
                HostTensor::F32(vec![lr]),
                HostTensor::F32(vec![wd]),
            ],
        )
        .unwrap();
    let hlo_p = out[0].as_f32().unwrap();

    let mut host_p = p0.clone();
    let mut opt = dilocox::optim::AdamW::new(n, lr, wd);
    opt.step(&mut host_p, &grads);

    for (a, b) in host_p.iter().zip(hlo_p) {
        assert!((a - b).abs() < 1e-6 + 1e-5 * b.abs(), "{a} vs {b}");
    }
}

#[test]
fn host_nesterov_matches_hlo_nesterov() {
    let Some(rt) = bundle("tiny") else { return };
    let man = &rt.manifest;
    let n = man.param_count;
    let p0 = man.read_f32(&man.init["single"].file).unwrap();
    let delta: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) * 1e-3).collect();
    let buf = vec![0.01f32; n];
    let (lr, mu) = (0.7f32, 0.9f32);

    let out = rt
        .exec(
            "nesterov_single",
            &[
                HostTensor::F32(p0.clone()),
                HostTensor::F32(delta.clone()),
                HostTensor::F32(buf.clone()),
                HostTensor::F32(vec![lr]),
                HostTensor::F32(vec![mu]),
            ],
        )
        .unwrap();
    let hlo_p = out[0].as_f32().unwrap();
    let hlo_buf = out[1].as_f32().unwrap();

    let mut host_p = p0.clone();
    let mut opt = dilocox::optim::Nesterov::new(n, lr, mu);
    opt.buf.copy_from_slice(&buf);
    opt.step(&mut host_p, &delta);

    for ((a, b), (c, d)) in
        host_p.iter().zip(hlo_p).zip(opt.buf.iter().zip(hlo_buf))
    {
        assert!((a - b).abs() < 1e-6 + 1e-5 * b.abs(), "params {a} vs {b}");
        assert!((c - d).abs() < 1e-6 + 1e-5 * d.abs(), "buf {c} vs {d}");
    }
}

#[test]
fn rust_lowrank_matches_hlo_lowrank_program() {
    // The L3-native PowerSGD iteration must agree with the exported
    // (pallas-lowered) lowrank_iter HLO on the same inputs.
    let Some(rt) = bundle("tiny") else { return };
    let man = &rt.manifest;
    if !man.programs.contains_key("lowrank_iter") {
        return;
    }
    let (inputs, _) = &man.goldens["lowrank_iter"];
    let m = man.read_f32(&format!("goldens/{}", inputs[0])).unwrap();
    let q = man.read_f32(&format!("goldens/{}", inputs[1])).unwrap();
    let sig = &man.program("lowrank_iter").unwrap().inputs;
    let (rows, cols) = (sig[0].shape[0], sig[0].shape[1]);
    let r = sig[1].shape[1];

    let out = rt
        .exec(
            "lowrank_iter",
            &[HostTensor::F32(m.clone()), HostTensor::F32(q.clone())],
        )
        .unwrap();
    let hlo_p = out[0].as_f32().unwrap();
    let hlo_q = out[1].as_f32().unwrap();

    use dilocox::linalg::{lowrank_iter, Mat};
    let (p_host, q_host) = lowrank_iter(
        &Mat::from_vec(rows, cols, m),
        &Mat::from_vec(cols, r, q),
    );
    for (a, b) in p_host.data.iter().zip(hlo_p) {
        assert!((a - b).abs() < 1e-3 + 1e-3 * b.abs(), "P: {a} vs {b}");
    }
    for (a, b) in q_host.data.iter().zip(hlo_q) {
        assert!((a - b).abs() < 1e-2 + 1e-3 * b.abs(), "Q: {a} vs {b}");
    }
}
