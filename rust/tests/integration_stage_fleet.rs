//! Stage-parallel TCP fleet integration: a 2-cluster × 2-stage fleet of
//! real `dilocox worker --stage` OS processes on loopback must be
//! bit-for-bit identical to the local threaded stage-parallel executor
//! (same schedule, same ring algebra, same engine), and a seeded kill of
//! one stage process mid-round must re-form the surviving per-stage rings
//! and still complete with a final eval.

use dilocox::compress::Method;
use dilocox::pipeline::exec::{
    local_stage_rings, run_pipeline, PipelineRunOpts, SyntheticPipeline,
};
use dilocox::pipeline::ScheduleKind;
use dilocox::transport::elastic::{run_elastic, ElasticConfig, SpawnMode};

fn dilocox_bin() -> String {
    env!("CARGO_BIN_EXE_dilocox").to_string()
}

/// Shared hyperparameters: sync mode, uncompressed fp32 rings — every
/// floating-point operation sequence must match between deployments.
const ROUNDS: usize = 3;
const LOCAL_STEPS: usize = 4;
const DIM: usize = 16;
const SEED: u64 = 4242;

fn fleet_cfg(clusters: usize, stages: usize) -> ElasticConfig {
    let mut cfg = ElasticConfig::synthetic_pipeline(clusters, stages, ROUNDS, DIM);
    cfg.local_steps = LOCAL_STEPS;
    cfg.seed = SEED;
    cfg.transport.ring_timeout_ms = 2000;
    cfg.transport.connect_timeout_ms = 8000;
    cfg.wall_timeout_ms = 90_000;
    cfg
}

fn local_opts() -> PipelineRunOpts {
    PipelineRunOpts {
        rounds: ROUNDS,
        local_steps: LOCAL_STEPS,
        inner_lr: 0.05,
        weight_decay: 0.0,
        outer_lr: 0.7,
        outer_momentum: 0.6,
        overlap: false,
        error_feedback: false,
        method: Method::None,
        seed: SEED,
        comm_pool_size: 1,
        pipeline_depth: 1,
        schedule: ScheduleKind::OneFOneB,
        virtual_stages: 1,
    }
}

#[test]
fn tcp_stage_fleet_overlap_matches_local_threaded_run_bit_for_bit() {
    // One-step-delay overlap, stage-parallel, across OS processes: the
    // fleet must execute the identical instruction sequence as the local
    // threaded executor (both run the shared RoundDriver + StageStepWork)
    // — final params, eval, and wire ledger all agree exactly.  2
    // clusters keep the fleet's epoch-1 consensus resync bit-exact
    // ((x+x)·0.5 == x), matching the resync-free threaded path.
    let (dp, stages, micros) = (2usize, 2usize, 2usize);
    let wl = SyntheticPipeline::new(stages, micros, DIM, SEED);
    // Gentle outer settings for overlap on the fast affine chain (see
    // the executor's overlap test).
    let mut o = local_opts();
    o.overlap = true;
    o.outer_lr = 0.3;
    o.outer_momentum = 0.3;
    let local =
        run_pipeline(&wl, dp, local_stage_rings(dp, stages), &o).unwrap();

    let mut cfg = fleet_cfg(dp, stages);
    cfg.overlap = true;
    cfg.outer_lr = 0.3;
    cfg.outer_momentum = 0.3;
    assert_eq!(cfg.microbatches, micros, "test assumes U = 2");
    let fleet =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();

    assert_eq!(fleet.epochs, 1, "no churn expected");
    assert_eq!(local.final_params, fleet.final_params);
    assert_eq!(local.final_eval, fleet.final_loss);
    assert_eq!(local.total_wire_bytes, fleet.total_wire_bytes);
    assert!(fleet.total_wire_bytes > 0);
    // Both ledgers show the one-step delay: nothing ships in round 1.
    assert!(local
        .reports
        .iter()
        .filter(|r| r.round == 1)
        .all(|r| r.wire_bytes == 0));
    assert!(fleet
        .round_wire
        .iter()
        .filter(|(_, r, _)| *r == 1)
        .all(|(_, _, b)| *b == 0));
    assert!(fleet
        .round_wire
        .iter()
        .filter(|(_, r, _)| *r == 2)
        .all(|(_, _, b)| *b > 0));
}

#[test]
fn tcp_stage_fleet_overlap_kill_drains_per_stage_and_completes() {
    // Kill one stage process mid-run under overlap.  Stage rings break
    // one round apart (the dead process's own ring stalls a round before
    // its downstream neighbors'), so the per-stage drain decisions fire
    // independently — the survivors finish each stage ring's held
    // reduction and the run completes every round with a finite
    // assembled eval.
    let mut cfg = fleet_cfg(3, 2);
    cfg.rounds = 5;
    cfg.overlap = true;
    cfg.outer_lr = 0.3;
    cfg.outer_momentum = 0.3;
    cfg.faults.enabled = true;
    cfg.faults.kill_rank = 1;
    cfg.faults.kill_stage = 0;
    cfg.faults.kill_round = 2;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 2], "cluster 1 must be gone entirely");
    assert!(out.epochs >= 2, "epochs={}", out.epochs);
    assert!(
        out.recoveries.iter().any(|&(_, _, d)| d > 0),
        "expected at least one per-stage drain commit, got {:?}",
        out.recoveries
    );
    assert!(out.final_loss.is_finite());
    assert_eq!(out.final_params.len(), 2 * DIM);
    let max_round = out
        .round_losses
        .iter()
        .map(|(_, r, _)| *r)
        .max()
        .unwrap_or(0);
    assert_eq!(max_round as usize, cfg.rounds);
}

#[test]
fn tcp_stage_fleet_overlap_soft_break_discards_and_everyone_survives() {
    // A soft cluster-wide break under overlap: every stage process of
    // cluster 1 parks at round 3 holding round-2 deltas while the other
    // clusters run ahead to round-3 deltas — mixed in-flight evidence on
    // every stage ring, so the coordinator must DISCARD (fold into error
    // feedback).  Nobody dies; the breaker rejoins and the whole fleet
    // completes.
    let mut cfg = fleet_cfg(3, 2);
    cfg.rounds = 6;
    cfg.overlap = true;
    cfg.outer_lr = 0.3;
    cfg.outer_momentum = 0.3;
    cfg.faults.enabled = true;
    cfg.faults.break_rank = 1;
    cfg.faults.break_round = 3;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 1, 2], "nobody died");
    assert!(out.epochs >= 2, "epochs={}", out.epochs);
    assert!(
        out.recoveries.iter().all(|&(_, _, d)| d == 0),
        "mixed in-flight must discard, got {:?}",
        out.recoveries
    );
    assert!(out.final_loss.is_finite());
    let max_round = out
        .round_losses
        .iter()
        .map(|(_, r, _)| *r)
        .max()
        .unwrap_or(0);
    assert_eq!(max_round as usize, cfg.rounds);
}

#[test]
fn tcp_stage_fleet_matches_local_threaded_run_bit_for_bit() {
    let (dp, stages, micros) = (2usize, 2usize, 2usize);
    // Local: one thread per (worker, stage), mpsc links, mpsc rings.
    let wl = SyntheticPipeline::new(stages, micros, DIM, SEED);
    let local =
        run_pipeline(&wl, dp, local_stage_rings(dp, stages), &local_opts())
            .unwrap();

    // TCP: one OS process per (cluster, stage), TCP stage links, per-stage
    // loopback-TCP rings, spawned via std::process::Command.
    let cfg = fleet_cfg(dp, stages);
    assert_eq!(cfg.microbatches, micros, "test assumes U = 2");
    let fleet =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();

    assert_eq!(fleet.started, dp);
    assert_eq!(fleet.survivors, vec![0, 1]);
    assert_eq!(fleet.epochs, 1, "no churn expected");
    // The headline guarantee: identical schedule + identical fp order on
    // every wire ⇒ the assembled final parameters agree EXACTLY.
    assert_eq!(local.final_params, fleet.final_params);
    assert_eq!(local.final_eval, fleet.final_loss);
    // Unified wire accounting: per-stage ring payloads sum identically.
    assert_eq!(local.total_wire_bytes, fleet.total_wire_bytes);
    assert!(fleet.total_wire_bytes > 0);
}

#[test]
fn tcp_stage_fleet_survives_stage_process_kill_at_round_2() {
    // Seeded churn: the stage-0 process of cluster 1 exits at the start
    // of round 2.  Its whole cluster drops out (the sibling stage starves
    // and is shut down), the surviving clusters' per-stage rings re-form
    // on a bumped epoch, and the run completes every round with a finite
    // assembled eval.
    let mut cfg = fleet_cfg(3, 2);
    cfg.rounds = 5;
    cfg.faults.enabled = true;
    cfg.faults.kill_rank = 1;
    cfg.faults.kill_stage = 0;
    cfg.faults.kill_round = 2;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 2], "cluster 1 must be gone entirely");
    assert!(
        out.epochs >= 2,
        "per-stage rings must have re-formed, epochs={}",
        out.epochs
    );
    assert!(out.final_loss.is_finite());
    assert_eq!(out.final_params.len(), 2 * DIM);
    // Survivors completed the full schedule after recovery.
    let max_round = out
        .round_losses
        .iter()
        .map(|(_, r, _)| *r)
        .max()
        .unwrap_or(0);
    assert_eq!(max_round as usize, cfg.rounds);
    // The survivor rings still converge (per-stage means rescaled to the
    // two remaining clusters).
    let r1: Vec<f32> = out
        .round_losses
        .iter()
        .filter(|(_, r, _)| *r == 1)
        .map(|(_, _, l)| *l)
        .collect();
    assert!(!r1.is_empty());
    let r1_mean = r1.iter().sum::<f32>() / r1.len() as f32;
    assert!(
        out.final_loss < r1_mean,
        "final {} vs round-1 {}",
        out.final_loss,
        r1_mean
    );
}

#[test]
fn tcp_zero_bubble_stage_fleet_matches_local_threaded_run_bit_for_bit() {
    // The ZB-H1 stream across OS processes: split backward (B then W),
    // back-filled weight grads, same fp order on every wire — the fleet
    // must agree EXACTLY with the threaded executor running the same
    // schedule.
    let (dp, stages, micros) = (2usize, 2usize, 2usize);
    let wl = SyntheticPipeline::new(stages, micros, DIM, SEED);
    let mut o = local_opts();
    o.schedule = ScheduleKind::ZeroBubble;
    let local =
        run_pipeline(&wl, dp, local_stage_rings(dp, stages), &o).unwrap();

    let mut cfg = fleet_cfg(dp, stages);
    cfg.schedule = "zero-bubble".into();
    assert_eq!(cfg.microbatches, micros, "test assumes U = 2");
    let fleet =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();

    assert_eq!(fleet.epochs, 1, "no churn expected");
    assert_eq!(local.final_params, fleet.final_params);
    assert_eq!(local.final_eval, fleet.final_loss);
    assert_eq!(local.total_wire_bytes, fleet.total_wire_bytes);
    assert!(fleet.total_wire_bytes > 0);
}

#[test]
fn tcp_zero_bubble_stage_fleet_kill_drains_and_completes() {
    // Churn on the zero-bubble process fleet with overlap: kill the
    // stage-0 process of cluster 1 at round 2; the survivors drain the
    // held per-stage reductions and finish every round.
    let mut cfg = fleet_cfg(3, 2);
    cfg.rounds = 5;
    cfg.schedule = "zero-bubble".into();
    cfg.overlap = true;
    cfg.outer_lr = 0.3;
    cfg.outer_momentum = 0.3;
    cfg.faults.enabled = true;
    cfg.faults.kill_rank = 1;
    cfg.faults.kill_stage = 0;
    cfg.faults.kill_round = 2;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 2], "cluster 1 must be gone entirely");
    assert!(out.epochs >= 2, "epochs={}", out.epochs);
    assert!(
        out.recoveries.iter().any(|&(_, _, d)| d > 0),
        "expected at least one per-stage drain commit, got {:?}",
        out.recoveries
    );
    assert!(out.final_loss.is_finite());
    let max_round = out
        .round_losses
        .iter()
        .map(|(_, r, _)| *r)
        .max()
        .unwrap_or(0);
    assert_eq!(max_round as usize, cfg.rounds);
}

#[test]
fn tcp_zero_bubble_stage_fleet_soft_break_discards() {
    // Soft break on the zero-bubble process fleet: cluster 1 parks at
    // round 3 with stale in-flight deltas — every stage ring must
    // discard, nobody dies, the run completes.
    let mut cfg = fleet_cfg(3, 2);
    cfg.rounds = 6;
    cfg.schedule = "zero-bubble".into();
    cfg.overlap = true;
    cfg.outer_lr = 0.3;
    cfg.outer_momentum = 0.3;
    cfg.faults.enabled = true;
    cfg.faults.break_rank = 1;
    cfg.faults.break_round = 3;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 1, 2], "nobody died");
    assert!(out.epochs >= 2, "epochs={}", out.epochs);
    assert!(
        out.recoveries.iter().all(|&(_, _, d)| d == 0),
        "mixed in-flight must discard, got {:?}",
        out.recoveries
    );
    assert!(out.final_loss.is_finite());
    let max_round = out
        .round_losses
        .iter()
        .map(|(_, r, _)| *r)
        .max()
        .unwrap_or(0);
    assert_eq!(max_round as usize, cfg.rounds);
}

#[test]
fn tcp_interleaved_stage_fleet_matches_local_threaded_run_bit_for_bit() {
    // v=2 chunks per executor process over a 4-stage model: the wrap
    // links close the process chain into a ring, and the chunked
    // per-exec rings must still reproduce the threaded executor exactly.
    let (dp, stages, micros, v) = (2usize, 4usize, 2usize, 2usize);
    let wl = SyntheticPipeline::new(stages, micros, DIM, SEED);
    let mut o = local_opts();
    o.schedule = ScheduleKind::Interleaved;
    o.virtual_stages = v;
    let local =
        run_pipeline(&wl, dp, local_stage_rings(dp, stages), &o).unwrap();

    let mut cfg = fleet_cfg(dp, stages);
    cfg.schedule = "interleaved".into();
    cfg.virtual_stages = v;
    assert_eq!(cfg.microbatches, micros, "test assumes U = 2");
    let fleet =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();

    assert_eq!(fleet.epochs, 1, "no churn expected");
    assert_eq!(local.final_params, fleet.final_params);
    assert_eq!(local.final_eval, fleet.final_loss);
    assert_eq!(local.total_wire_bytes, fleet.total_wire_bytes);
}

#[test]
fn deterministic_port_layout_fleet_runs() {
    // stage_listen_base_port pins every listener to a computed port; the
    // fleet must come up and converge on the fixed layout too.
    let mut cfg = fleet_cfg(2, 2);
    // Below the usual Linux ephemeral range (32768+) to avoid collisions
    // with other tests' OS-assigned ports.
    cfg.transport.stage_listen_base_port = 24310;
    let out =
        run_elastic(&cfg, &SpawnMode::Process { exe: dilocox_bin() }).unwrap();
    assert_eq!(out.survivors, vec![0, 1]);
    assert!(out.final_loss.is_finite());
}
