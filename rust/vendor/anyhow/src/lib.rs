//! Minimal offline reimplementation of the `anyhow` API surface that the
//! dilocox crate uses: [`Error`], [`Result`], the [`Context`] extension
//! trait, and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirror upstream closely enough for this workspace:
//! * `Error` is a cheap string-chain (outermost context first).
//! * `Display` prints the outermost message; `{:#}` prints the full chain
//!   joined by `": "`; `Debug` prints the chain too (what `{:?}`/`{:#}` in
//!   `main` error paths rely on).
//! * Any `std::error::Error + Send + Sync + 'static` converts into `Error`
//!   via `?`.
//!
//! Swap this path dependency for the real `anyhow = "1"` when offline
//! builds are not a constraint — no call sites need to change.

use std::fmt;

/// String-chain error: `chain[0]` is the outermost context.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { chain: vec![msg.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, msg: impl fmt::Display) -> Error {
        self.chain.insert(0, msg.to_string());
        self
    }

    /// The full cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve the std source chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, msg: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))?;
        Ok(())
    }

    #[test]
    fn macro_and_display() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = io_fail().context("loading config").unwrap_err();
        assert_eq!(e.to_string(), "loading config");
        assert_eq!(format!("{e:#}"), "loading config: disk on fire");
        assert_eq!(e.root_cause(), "disk on fire");
    }

    #[test]
    fn with_context_and_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(3).is_err());
        assert!(f(11).is_err());
    }
}
