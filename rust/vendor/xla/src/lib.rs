//! Offline stub of the `xla_extension` binding surface used by
//! `dilocox::runtime`.  Every entry point type-checks against the call
//! sites; [`PjRtClient::cpu`] reports that the PJRT backend is unavailable,
//! so anything that would execute HLO fails fast with a clear message.
//!
//! The crate exists so the workspace builds (and the non-PJRT 95% of the
//! system — transport, compression, DES simulator, collectives — runs and
//! tests) on machines without the XLA shared library.  Swap this path
//! dependency for the real bindings to enable real-numerics runs; no call
//! sites change.

use std::fmt;
use std::path::Path;

/// Error type; call sites only format it with `{:?}`.
pub struct XlaError(pub String);

impl fmt::Debug for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "XlaError({})", self.0)
    }
}

const UNAVAILABLE: &str =
    "PJRT backend unavailable: built with the offline xla stub \
     (rust/vendor/xla); swap in the real xla_extension bindings";

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(UNAVAILABLE.to_string()))
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}
